#!/usr/bin/env bash
# Reproducible tier-1 entry point.
#
#   scripts/ci.sh               fast tier-1: the @sharded suite
#                               (mesh-native engines, subprocesses with
#                               4 forced host devices) first, then the
#                               @mixed suite (unified mixed-batch
#                               plane), then the @paged property suite
#                               (block allocator + cache surgery), then
#                               the full suite minus @slow model cases,
#                               then the benchmark smoke
#                               (microbench + quick e2e_pd emitting
#                               BENCH_e2e.json) guarded against the
#                               committed baseline (>25% TTFT-p99 or
#                               throughput regression fails)
#   scripts/ci.sh --full        everything, including @slow cases (the
#                               cross-plane sim/real × padded/paged
#                               equivalence sweep lives here;
#                               equivalent to the ROADMAP tier-1 command
#                               `pytest -x -q`)
#   scripts/ci.sh --real-smoke  real-engine smoke: examples/serve_e2e.py
#                               through the REAL P/D ClusterRuntime plane
#                               with the paged KV cache, compared against
#                               padded slots at equal memory — fails on
#                               any unfinished request or if paged does
#                               not sustain strictly higher concurrent
#                               decode; records the result in
#                               BENCH_e2e.json [real_plane].  Then the
#                               prefix-cache A/B [real_plane_prefix] and
#                               the SLO-overload A/B — page-level
#                               preemption must post strictly higher
#                               goodput than drain-only at equal KV
#                               memory [real_plane_overload].  Finally
#                               the unified mixed-batch A/B — chunked
#                               prefill piggybacked into the decode
#                               steps must post a strictly lower ITL p99
#                               at equal-or-higher throughput than the
#                               disjoint (prefill-prioritizing) ablation
#                               [real_plane_mixed].  Finally the sharded
#                               DP+EP A/B on a 4-device forced-host
#                               mesh — with the EP all-to-all verified
#                               in the compiled step HLO, sbs-la's
#                               aligned batch formation must post a
#                               strictly lower TTFT p99 than immediate
#                               dispatch at equal-or-higher throughput,
#                               and the measured per-step sync time
#                               calibrates CostModel.t_sync
#                               [real_plane_sharded]
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${1:-}" == "--real-smoke" ]]; then
    echo "== real-engine smoke (serve_e2e paged vs padded, 150s budget) =="
    PYTHONPATH=src timeout 150 python examples/serve_e2e.py \
        --arch granite-moe-1b-a400m --requests 10 --max-new 12 \
        --max-batch-per-dp 1 --arrival-spacing 0 \
        --schedulers sbs-la --timeout 110 --compare-padded \
        --bench-json BENCH_e2e.json \
        || { echo "real smoke FAILED (unfinished requests, paged <= padded" \
                  "concurrency, or >150s)" >&2
             exit 1; }
    echo "== real-plane prefix-cache A/B (shared tenants, 300s budget) =="
    PYTHONPATH=src timeout 300 python examples/serve_e2e.py \
        --requests 10 --max-new 4 --timeout 150 \
        --prefix-bench --bench-json BENCH_e2e.json \
        || { echo "prefix smoke FAILED (no FLOPs saved, cached ttft_p99" \
                  "not lower, unfinished requests, or >300s)" >&2
             exit 1; }
    echo "== real-plane SLO-overload A/B (preempt vs drain-only, 300s budget) =="
    PYTHONPATH=src timeout 300 python examples/serve_e2e.py \
        --timeout 150 --overload-bench --bench-json BENCH_e2e.json \
        || { echo "overload smoke FAILED (preempting goodput not strictly" \
                  "above drain-only, no preemptions, unfinished requests," \
                  "or >300s)" >&2
             exit 1; }
    echo "== real-plane mixed-batch A/B (piggyback vs disjoint, 600s budget) =="
    PYTHONPATH=src timeout 600 python examples/serve_e2e.py \
        --timeout 150 --mixed-bench --bench-json BENCH_e2e.json \
        || { echo "mixed smoke FAILED (piggyback itl_p99 not strictly" \
                  "below disjoint at equal-or-higher throughput," \
                  "unfinished requests, or >600s)" >&2
             exit 1; }
    echo "== real-plane sharded DP+EP A/B (sbs-la vs immediate, 600s budget) =="
    PYTHONPATH=src timeout 600 python examples/serve_e2e.py \
        --arch granite-moe-1b-a400m --timeout 150 \
        --sharded-bench --bench-json BENCH_e2e.json \
        || { echo "sharded smoke FAILED (EP all-to-all absent from step" \
                  "HLO, sbs-la ttft_p99 not strictly below immediate at" \
                  "equal-or-higher throughput, unfinished requests, or" \
                  ">600s)" >&2
             exit 1; }
    echo "REAL SMOKE OK"
    exit 0
fi

echo "== tier-1 tests =="
if [[ "${1:-}" == "--full" ]]; then
    PYTHONPATH=src python -m pytest -q
else
    # sharded mesh-native suite first (fail fast on the newest
    # subsystem; its multi-device cases subprocess with their own
    # forced host devices), then mixed-batch, then the paged KV
    # property suite, then everything else; @slow — including the
    # heavyweight cross-plane equivalence sweep — stays behind --full
    PYTHONPATH=src python -m pytest -q -m "sharded and not slow"
    PYTHONPATH=src python -m pytest -q -m "mixed and not slow and not sharded"
    PYTHONPATH=src python -m pytest -q \
        -m "paged and not slow and not mixed and not sharded"
    PYTHONPATH=src python -m pytest -q \
        -m "not slow and not paged and not mixed and not sharded"
fi

echo "== benchmark smoke (microbench) =="
out=$(PYTHONPATH=src:. python benchmarks/run.py --only microbench)
echo "$out"
if grep -q "BENCH FAILED" <<<"$out"; then
    echo "benchmark smoke FAILED" >&2
    exit 1
fi

echo "== benchmark smoke (e2e_pd --quick --json -> BENCH_e2e.json) =="
baseline=""
if git show HEAD:BENCH_e2e.json >/tmp/bench_baseline.json 2>/dev/null; then
    baseline=/tmp/bench_baseline.json
fi
out=$(PYTHONPATH=src:. python benchmarks/run.py --only e2e_pd --quick --json)
echo "$out"
if grep -q "BENCH FAILED" <<<"$out" || [[ ! -s BENCH_e2e.json ]]; then
    echo "e2e_pd smoke FAILED (no BENCH_e2e.json)" >&2
    exit 1
fi

echo "== bench regression guard (fresh --quick vs committed baseline) =="
if [[ -n "$baseline" ]]; then
    # --section e2e_quick: only the rows this --quick run regenerated are
    # judged (e2e_full rows in the working tree are passthrough data)
    python scripts/check_bench_regression.py "$baseline" BENCH_e2e.json \
        --threshold 0.25 --section e2e_quick \
        || { echo "bench regression guard FAILED" >&2; exit 1; }
else
    echo "no committed BENCH_e2e.json baseline; guard skipped"
fi
echo "CI OK"
