#!/usr/bin/env bash
# Reproducible tier-1 entry point.
#
#   scripts/ci.sh          fast tier-1: full suite minus @slow model cases
#                          + a smoke invocation of the benchmark harness
#   scripts/ci.sh --full   everything, including @slow cases (equivalent
#                          to the ROADMAP tier-1 command `pytest -x -q`)
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1 tests =="
if [[ "${1:-}" == "--full" ]]; then
    PYTHONPATH=src python -m pytest -q
else
    PYTHONPATH=src python -m pytest -q -m "not slow"
fi

echo "== benchmark smoke (microbench) =="
out=$(PYTHONPATH=src:. python benchmarks/run.py --only microbench)
echo "$out"
if grep -q "BENCH FAILED" <<<"$out"; then
    echo "benchmark smoke FAILED" >&2
    exit 1
fi
echo "CI OK"
