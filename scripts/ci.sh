#!/usr/bin/env bash
# Reproducible tier-1 entry point.
#
#   scripts/ci.sh               fast tier-1: full suite minus @slow model
#                               cases + benchmark smoke (microbench + quick
#                               e2e_pd emitting BENCH_e2e.json)
#   scripts/ci.sh --full        everything, including @slow cases
#                               (equivalent to the ROADMAP tier-1 command
#                               `pytest -x -q`)
#   scripts/ci.sh --real-smoke  real-engine smoke only: examples/serve_e2e.py
#                               on a tiny config through the REAL P/D
#                               ClusterRuntime plane, 60s budget, failing on
#                               any unfinished request
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${1:-}" == "--real-smoke" ]]; then
    echo "== real-engine smoke (serve_e2e, 60s budget) =="
    PYTHONPATH=src timeout 60 python examples/serve_e2e.py \
        --arch granite-moe-1b-a400m --requests 4 --max-new 3 \
        --schedulers sbs-la --timeout 55 \
        || { echo "real smoke FAILED (unfinished requests or >60s)" >&2
             exit 1; }
    echo "REAL SMOKE OK"
    exit 0
fi

echo "== tier-1 tests =="
if [[ "${1:-}" == "--full" ]]; then
    PYTHONPATH=src python -m pytest -q
else
    PYTHONPATH=src python -m pytest -q -m "not slow"
fi

echo "== benchmark smoke (microbench) =="
out=$(PYTHONPATH=src:. python benchmarks/run.py --only microbench)
echo "$out"
if grep -q "BENCH FAILED" <<<"$out"; then
    echo "benchmark smoke FAILED" >&2
    exit 1
fi

echo "== benchmark smoke (e2e_pd --quick --json -> BENCH_e2e.json) =="
out=$(PYTHONPATH=src:. python benchmarks/run.py --only e2e_pd --quick --json)
echo "$out"
if grep -q "BENCH FAILED" <<<"$out" || [[ ! -s BENCH_e2e.json ]]; then
    echo "e2e_pd smoke FAILED (no BENCH_e2e.json)" >&2
    exit 1
fi
echo "CI OK"
