#!/usr/bin/env python
"""Bench regression guard: compare a fresh BENCH_e2e.json against the
committed baseline and fail on a large TTFT-p99 or throughput regression
for any (scenario, qps, scheduler) pair present in both files.

    python scripts/check_bench_regression.py BASELINE FRESH [--threshold 0.25]

Only metric dicts carrying both `ttft_p99` and `throughput` are compared
(auxiliary payload sections such as `real_plane` / `paged_concurrency`
are informational and skipped).  Rows whose baseline carries a positive
`prefix_hit_rate` (the shared_prefix scenario) are additionally guarded
against the cache-hit rate dropping by more than the threshold — a
silent loss of page reuse fails the build like a latency regression
would.  Likewise rows with a positive baseline `goodput` (every e2e
scenario, including the overload-control A/B section) fail on a goodput
drop beyond the threshold — overload control shedding load it used to
serve is a regression, not a tuning choice.  Rows with a positive
baseline `itl_p99` (inter-token latency, recorded since the unified
mixed-batch plane) fail on an ITL-p99 inflation beyond the threshold —
decode smoothness is the metric piggybacked prefill exists to protect.
Rows with a positive baseline `sync_stall_ms` (the sharded DP+EP A/B,
`real_plane_sharded`) fail on a stall-integral inflation beyond the
threshold — per-step cross-DP sync stall is the quantity aligned batch
formation exists to cut, so its regression is judged alongside TTFT.
The sims are deterministic, so the threshold guards real
scheduling/cost-model regressions, not noise — but --quick baselines
must be compared against --quick runs.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, Iterator, Tuple


def metric_rows(payload: Dict, path: Tuple[str, ...] = ()
                ) -> Iterator[Tuple[Tuple[str, ...], Dict]]:
    """Yield every (path, metrics) dict holding ttft_p99 + throughput."""
    if not isinstance(payload, dict):
        return
    if "ttft_p99" in payload and "throughput" in payload:
        yield path, payload
        return
    for key, val in payload.items():
        yield from metric_rows(val, path + (str(key),))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("fresh")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max tolerated fractional regression (default 25%)")
    ap.add_argument("--section", default=None,
                    help="compare only this top-level payload section "
                         "(e.g. e2e_quick) — restricts the guard to rows "
                         "the fresh run actually regenerated instead of "
                         "passthrough data merged from the existing file")
    args = ap.parse_args()

    def load(path):
        with open(path) as f:
            payload = json.load(f)
        if args.section is not None:
            payload = {args.section: payload.get(args.section, {})}
        return dict(metric_rows(payload))

    base = load(args.baseline)
    fresh = load(args.fresh)

    if not base:
        # a baseline with no comparable rows (e.g. it predates the
        # requested section / schema) cannot regress — skip, don't fail:
        # the very first run after a schema migration must stay green
        print("bench-guard: baseline has no comparable rows"
              + (f" for section {args.section!r}" if args.section else "")
              + "; guard skipped")
        return 0
    if not fresh:
        print("bench-guard: fresh payload has no comparable rows — the "
              "run produced nothing to judge", file=sys.stderr)
        return 1
    shared = sorted(set(base) & set(fresh))
    if not shared:
        print("bench-guard: no overlapping (scenario,qps,scheduler) pairs "
              "between baseline and fresh payloads", file=sys.stderr)
        return 1

    failures = []
    print(f"bench-guard: {len(shared)} pairs, threshold "
          f"{args.threshold:.0%}")
    for path in shared:
        b, f_ = base[path], fresh[path]
        name = "/".join(path)
        ttft_ratio = (f_["ttft_p99"] / b["ttft_p99"]
                      if b["ttft_p99"] > 0 else 1.0)
        thr_ratio = (f_["throughput"] / b["throughput"]
                     if b["throughput"] > 0 else 1.0)
        verdicts = []
        if ttft_ratio > 1.0 + args.threshold:
            verdicts.append(f"ttft_p99 {ttft_ratio - 1:+.1%}")
        if thr_ratio < 1.0 - args.threshold:
            verdicts.append(f"throughput {thr_ratio - 1:+.1%}")
        hit_note = ""
        if b.get("prefix_hit_rate", 0.0) > 0.0:
            hit_ratio = f_.get("prefix_hit_rate", 0.0) / b["prefix_hit_rate"]
            hit_note = f" hit x{hit_ratio:.3f}"
            if hit_ratio < 1.0 - args.threshold:
                verdicts.append(f"prefix_hit_rate {hit_ratio - 1:+.1%}")
        if b.get("goodput", 0.0) > 0.0:
            good_ratio = f_.get("goodput", 0.0) / b["goodput"]
            hit_note += f" good x{good_ratio:.3f}"
            if good_ratio < 1.0 - args.threshold:
                verdicts.append(f"goodput {good_ratio - 1:+.1%}")
        if b.get("itl_p99", 0.0) > 0.0:
            itl_ratio = f_.get("itl_p99", 0.0) / b["itl_p99"]
            hit_note += f" itl x{itl_ratio:.3f}"
            if itl_ratio > 1.0 + args.threshold:
                verdicts.append(f"itl_p99 {itl_ratio - 1:+.1%}")
        if b.get("sync_stall_ms", 0.0) > 0.0:
            stall_ratio = f_.get("sync_stall_ms", 0.0) / b["sync_stall_ms"]
            hit_note += f" stall x{stall_ratio:.3f}"
            if stall_ratio > 1.0 + args.threshold:
                verdicts.append(f"sync_stall_ms {stall_ratio - 1:+.1%}")
        status = "FAIL " + ", ".join(verdicts) if verdicts else "ok"
        print(f"  {name:<44} ttft_p99 x{ttft_ratio:.3f} "
              f"thr x{thr_ratio:.3f}{hit_note}  {status}")
        if verdicts:
            failures.append((name, verdicts))

    if failures:
        print(f"bench-guard: {len(failures)} regressed pair(s) beyond "
              f"{args.threshold:.0%}", file=sys.stderr)
        return 1
    print("bench-guard: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
