"""Serving launcher: run the SBS control plane.

Two modes:
  --mode sim   discrete-event cluster simulation at production scale
               (reproduces the paper's §5 numbers; default)
  --mode real  real JAX execution of a reduced model behind the SBS
               scheduler (threaded engines, true chunked prefill + decode)

    PYTHONPATH=src python -m repro.launch.serve --mode sim \
        --arch deepseek-v3-671b --scheduler sbs --qps 100 --duration 20
    PYTHONPATH=src python -m repro.launch.serve --mode real \
        --arch deepseek-7b --requests 8
"""
from __future__ import annotations

import argparse
import random


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="sim", choices=["sim", "real"])
    ap.add_argument("--arch", default="deepseek-v3-671b")
    ap.add_argument("--scheduler", default="sbs",
                    choices=["sbs", "immediate-rr", "immediate-lt"])
    ap.add_argument("--workload", default="short",
                    choices=["short", "long", "decode"])
    ap.add_argument("--qps", type=float, default=100.0)
    ap.add_argument("--duration", type=float, default=20.0)
    ap.add_argument("--chunk", type=int, default=3072)
    ap.add_argument("--prefill-instances", type=int, default=3)
    ap.add_argument("--dp-per-instance", type=int, default=8)
    ap.add_argument("--cache-aware", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.config.base import ServingConfig, get_arch

    if args.mode == "sim":
        from repro.serving.cluster import PrefillClusterSim
        from repro.serving.workload import SPECS, generate
        cfg = get_arch(args.arch)
        scfg = ServingConfig(
            num_prefill_instances=args.prefill_instances,
            prefill_dp_per_instance=args.dp_per_instance,
            chunk_size=args.chunk, cache_aware=args.cache_aware,
            t_default=0.1)
        reqs = generate(SPECS[args.workload], qps=args.qps,
                        duration=args.duration, seed=args.seed,
                        with_tokens=args.cache_aware,
                        shared_prefix_prob=0.5 if args.cache_aware else 0.0)
        sim = PrefillClusterSim(cfg, scfg, scheduler=args.scheduler)
        rep = sim.run(reqs, args.duration)
        print(f"{args.scheduler} @ {args.qps} qps: {rep.row()}")
        return

    # real execution (reduced model)
    import jax
    from repro.core.types import Request
    from repro.models import init_params
    from repro.serving.server import RealSBSServer
    cfg = get_arch(args.arch, reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = random.Random(args.seed)
    reqs = []
    for i in range(args.requests):
        L = rng.randrange(16, 96)
        reqs.append(Request(
            rid=i, arrival_time=i * 0.05, input_len=L, output_len=8,
            tokens=tuple(rng.randrange(cfg.vocab_size) for _ in range(L))))
    srv = RealSBSServer(
        cfg, params,
        scheduler="sbs" if args.scheduler == "sbs" else "immediate",
        max_len=160, max_new=8)
    gens = srv.serve(reqs, timeout=300)
    for g in gens:
        print(f"rid={g.rid} ttft={g.ttft*1000:7.1f}ms tokens={g.tokens}")
    print(f"served {len(gens)}/{len(reqs)}; "
          f"adapted I_opt={srv.state.interval.interval*1000:.1f}ms")


if __name__ == "__main__":
    main()
