"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state. The dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512
before any jax import; everything else sees the real (1-device) platform.
"""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 (one v5e pod, 256 chips) or 2×16×16 (2 pods, 512 chips)."""
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"need {need} devices for {shape}, have {len(devices)} — run via "
            "launch/dryrun.py (it forces 512 host devices)")
    return jax.make_mesh(shape, axes, devices=devices[:need])


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for CPU tests (requires forced host device count)."""
    import jax
    if pod:
        shape, axes = (pod, data, model), ("pod", "data", "model")
    else:
        shape, axes = (data, model), ("data", "model")
    need = int(np.prod(shape))
    return jax.make_mesh(shape, axes, devices=jax.devices()[:need])
