"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state. The dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512
before any jax import; everything else sees the real (1-device) platform.
"""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 (one v5e pod, 256 chips) or 2×16×16 (2 pods, 512 chips)."""
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"need {need} devices for {shape}, have {len(devices)} — run via "
            "launch/dryrun.py (it forces 512 host devices)")
    return jax.make_mesh(shape, axes, devices=devices[:need])


def make_engine_mesh(data: int, model: int = 1):
    """Mesh for the SHARDED real serving plane: one data-axis rank per
    decode DP unit (the merged paged cache's slot/pool dims shard over
    "data"), `model` ranks of tensor parallelism inside each DP.  CI
    drives this with forced host devices
    (XLA_FLAGS=--xla_force_host_platform_device_count=N, set BEFORE the
    first jax import); production uses the real accelerator topology."""
    import jax
    need = data * model
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"sharded plane needs {need} devices for a ({data},{model}) "
            f"data×model mesh, have {len(devices)} — force host devices "
            f"with XLA_FLAGS=--xla_force_host_platform_device_count={need} "
            f"before the first jax import")
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=devices[:need])


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for CPU tests (requires forced host device count)."""
    import jax
    if pod:
        shape, axes = (pod, data, model), ("pod", "data", "model")
    else:
        shape, axes = (data, model), ("data", "model")
    need = int(np.prod(shape))
    return jax.make_mesh(shape, axes, devices=jax.devices()[:need])
