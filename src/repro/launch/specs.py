"""Abstract input specs + step functions for the multi-pod dry-run.

`input_specs(cfg, shape)` returns weak-type-correct ShapeDtypeStruct
stand-ins for every input of the step the shape exercises (train_step for
training shapes, prefill/serve_step for inference shapes) — no device
allocation ever happens; weights enter `.lower()` abstractly too.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import (
    InputShape, ModelConfig, ParallelConfig, TrainConfig,
)
from repro.models import abstract_cache, abstract_params, decode_step, prefill
from repro.models.model import forward_train
from repro.train.optimizer import adamw_init, adamw_update
from repro.train.schedule import make_schedule


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def applicable(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """Is this (arch × shape) combination runnable? (DESIGN.md §4 skips)."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, ("full-attention arch: long_500k requires "
                       "sub-quadratic attention (DESIGN.md §4)")
    return True, ""


def batch_inputs(cfg: ModelConfig, shape: InputShape,
                 dtype=jnp.bfloat16) -> Dict:
    """Abstract inputs for the step this shape lowers."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = {
            "tokens": sds((B, S), jnp.int32),
            "targets": sds((B, S), jnp.int32),
        }
        if cfg.is_encoder_decoder:
            batch["embeds"] = sds((B, cfg.encoder_seq_len, cfg.d_model), dtype)
        elif cfg.num_patch_tokens:
            batch["embeds"] = sds((B, cfg.num_patch_tokens, cfg.d_model), dtype)
        return {"batch": batch}
    if shape.kind == "prefill":
        out = {"tokens": sds((B, S), jnp.int32)}
        if cfg.is_encoder_decoder:
            out["embeds"] = sds((B, cfg.encoder_seq_len, cfg.d_model), dtype)
        elif cfg.num_patch_tokens:
            out["embeds"] = sds((B, cfg.num_patch_tokens, cfg.d_model), dtype)
        return out
    # decode: one new token against a seq_len-deep cache
    cache = abstract_cache(cfg, B, S, dtype)
    return {"token": sds((B, 1), jnp.int32), "cache": cache}


def make_step_fn(cfg: ModelConfig, shape: InputShape,
                 tcfg: Optional[TrainConfig] = None, remat="block",
                 gather_shardings=None):
    """Returns (fn, donate_argnums). Signatures:
    train:   fn(params, opt_state, batch) -> (params, opt_state, loss)
    prefill: fn(params, tokens[, embeds]) -> (logits, cache)
    decode:  fn(params, token, cache) -> (logits, cache)

    gather_shardings (train + FSDP): NamedSharding tree WITHOUT the fsdp
    axes. Weights are all-gathered at use via a sharding constraint (the
    pjit ZeRO/FSDP idiom); autodiff transposes it into a reduce-scatter of
    the grads, so optimizer state stays fully sharded.
    """
    if shape.kind == "train":
        tcfg = tcfg or TrainConfig(global_batch=shape.global_batch,
                                   seq_len=shape.seq_len)
        schedule = make_schedule(tcfg.schedule, tcfg.lr, tcfg.warmup_steps,
                                 tcfg.total_steps, tcfg.stable_frac)

        def train_step(params, opt_state, batch):
            def loss_fn(p):
                if gather_shardings is not None:
                    p = jax.lax.with_sharding_constraint(p, gather_shardings)
                l, m = forward_train(cfg, p, batch, remat=remat)
                return l
            loss, grads = jax.value_and_grad(loss_fn)(params)
            lr = schedule(opt_state["step"])
            params, opt_state, _ = adamw_update(
                params, grads, opt_state, lr,
                beta1=tcfg.beta1, beta2=tcfg.beta2, eps=tcfg.eps,
                weight_decay=tcfg.weight_decay, grad_clip=tcfg.grad_clip)
            return params, opt_state, loss
        return train_step, (0, 1)

    if shape.kind == "prefill":
        if cfg.is_encoder_decoder or cfg.num_patch_tokens:
            def prefill_step(params, tokens, embeds):
                return prefill(cfg, params, tokens, embeds=embeds,
                               max_len=shape.seq_len, remat=remat)
        else:
            def prefill_step(params, tokens):
                return prefill(cfg, params, tokens,
                               max_len=shape.seq_len, remat=remat)
        return prefill_step, ()

    def serve_step(params, token, cache):
        return decode_step(cfg, params, token, cache)
    return serve_step, (2,)        # donate the cache


def abstract_opt_state(params):
    return jax.eval_shape(adamw_init, params)
