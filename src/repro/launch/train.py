"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --reduced \
        --steps 200 --batch 8 --seq 128 --schedule wsd --ckpt /tmp/ckpt

Full (non-reduced) configs are meant for the production mesh; on this CPU
container use --reduced (the ≤2-layer family-preserving variant).
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp

from repro.config.base import TrainConfig, get_arch
from repro.data import synthetic_batches
from repro.train import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", default="wsd",
                    choices=["wsd", "cosine", "constant"])
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--branching", type=int, default=4)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--save-every", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch, reduced=args.reduced)
    tcfg = TrainConfig(global_batch=args.batch, seq_len=args.seq, lr=args.lr,
                       schedule=args.schedule, warmup_steps=args.warmup,
                       total_steps=args.steps)
    trainer = Trainer(cfg, tcfg, ckpt_dir=args.ckpt)
    batches = synthetic_batches(cfg.vocab_size, args.batch, args.seq,
                                branching=args.branching)
    res = trainer.fit(batches, args.steps, log_every=args.log_every,
                      save_every=args.save_every)
    if args.ckpt:
        trainer.save()
    print(f"final ce={res['final_ce']:.4f} "
          f"(optimal = ln({args.branching}) = "
          f"{__import__('math').log(args.branching):.4f})")


if __name__ == "__main__":
    main()
