import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-7b \
        --shape prefill_32k --mesh single --out experiments/dryrun

The XLA_FLAGS line above MUST run before any jax import (device count locks
on first init) — hence its position as the first statement of the module.
"""

import argparse
import dataclasses
import json
import re
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import (
    INPUT_SHAPES, InputShape, ModelConfig, ParallelConfig, get_arch,
)
from repro.distributed.sharding import (
    batch_pspecs, cache_pspecs, named, opt_pspecs, param_pspecs,
)
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (
    abstract_opt_state, applicable, batch_inputs, make_step_fn,
)
from repro.models import abstract_params

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

# ---------------------------------------------------------------------------

def default_parallel(cfg: ModelConfig, shape: InputShape,
                     mesh) -> ParallelConfig:
    ex = ("model",)
    if cfg.moe.num_experts:
        import numpy as np
        for cand in (("data", "model"), ("model",)):
            if all(a in mesh.axis_names for a in cand):
                n = int(np.prod([mesh.shape[a] for a in cand]))
                if cfg.moe.num_experts % n == 0:
                    ex = cand
                    break
    return ParallelConfig(
        fsdp_params=(shape.kind == "train"),
        expert_axes=ex,
        remat=("block" if shape.kind == "train" else "none"),
        zero1=True,
    )


def lower_one(arch: str, shape_name: str, multi_pod: bool,
              compile_: bool = True, dtype=jnp.bfloat16,
              parallel: Optional[ParallelConfig] = None) -> Dict:
    cfg = get_arch(arch)
    shape = INPUT_SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    rec: Dict = {"arch": arch, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16"}
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    par = parallel or default_parallel(cfg, shape, mesh)
    t0 = time.time()

    from repro.distributed.annotate import activate
    from repro.distributed.sharding import data_axes_of
    model_size = mesh.shape.get(par.model_axis, 1)
    # attention-free (SSM) archs must NOT be sequence-sharded: the SSD scan
    # is sequential along S (measured: mamba2 train memory 1.0 → 4.2 s when
    # seq-sharded); treat them as "shardable" so attn_seq stays None.
    heads_shardable = (cfg.num_heads == 0
                       or cfg.num_heads % max(model_size, 1) == 0)
    axis_map = {
        "tokens": data_axes_of(mesh, par),
        "experts": tuple(a for a in par.expert_axes if a in mesh.axis_names),
        "model": par.model_axis,
        # seq-parallel fallback for awkward head counts (whisper 20H,
        # internvl2 14H, minicpm 36H, minicpm3 40H)
        "attn_seq": None if heads_shardable else par.model_axis,
    }
    ep_sm = os.environ.get("REPRO_EP", "auto") == "shard_map"
    ctx = activate(mesh, axis_map, ep_shard_map=ep_sm)
    ctx.__enter__()
    try:
        return _lower_inner(cfg, shape, mesh, par, rec, multi_pod, compile_,
                            dtype, t0)
    finally:
        ctx.__exit__(None, None, None)


def _lower_inner(cfg, shape, mesh, par, rec, multi_pod, compile_, dtype, t0):

    params_abs = abstract_params(cfg, dtype)
    p_specs = param_pspecs(cfg, mesh, par, params_abs)
    p_shard = named(mesh, p_specs)
    gather = None
    if shape.kind == "train" and par.fsdp_params:
        par_nofsdp = dataclasses.replace(par, fsdp_params=False)
        gather = named(mesh, param_pspecs(cfg, mesh, par_nofsdp, params_abs))
    fn, donate = make_step_fn(cfg, shape,
                              remat=os.environ.get("REPRO_REMAT", par.remat),
                              gather_shardings=gather)

    if shape.kind == "train":
        opt_abs = abstract_opt_state(params_abs)
        o_specs = {"mu": p_specs, "nu": p_specs,
                   "step": jax.sharding.PartitionSpec()}
        o_shard = named(mesh, o_specs)
        binputs = batch_inputs(cfg, shape, dtype)["batch"]
        b_shard = named(mesh, batch_pspecs(mesh, par, shape.global_batch,
                                           binputs))
        jfn = jax.jit(fn,
                      in_shardings=(p_shard, o_shard, b_shard),
                      out_shardings=(p_shard, o_shard, None),
                      donate_argnums=donate)
        lowered = jfn.lower(params_abs, opt_abs, binputs)
    elif shape.kind == "prefill":
        ins = batch_inputs(cfg, shape, dtype)
        b_shard = named(mesh, batch_pspecs(mesh, par, shape.global_batch,
                                           ins))
        args = [params_abs, ins["tokens"]]
        shards = [p_shard, b_shard["tokens"]]
        if "embeds" in ins:
            args.append(ins["embeds"])
            shards.append(b_shard["embeds"])
        jfn = jax.jit(fn, in_shardings=tuple(shards))
        lowered = jfn.lower(*args)
    else:  # decode
        ins = batch_inputs(cfg, shape, dtype)
        cache_specs = cache_pspecs(cfg, mesh, par, ins["cache"],
                                   shape.global_batch)
        c_shard = named(mesh, cache_specs)
        tok_shard = named(mesh, batch_pspecs(
            mesh, par, shape.global_batch, {"t": ins["token"]}))["t"]
        jfn = jax.jit(fn,
                      in_shardings=(p_shard, tok_shard, c_shard),
                      out_shardings=(None, c_shard),
                      donate_argnums=donate)
        lowered = jfn.lower(params_abs, ins["token"], ins["cache"])

    rec["lower_s"] = round(time.time() - t0, 2)
    if not compile_:
        rec["status"] = "lowered"
        return rec
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 2)

    # ---- analyses -----------------------------------------------------
    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k)) for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes")
            if hasattr(mem, k)}
    except Exception as e:  # pragma: no cover
        rec["memory"] = {"error": str(e)}
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        rec["xla_cost_raw"] = {"flops": float(cost.get("flops", 0.0)),
                               "bytes": float(cost.get("bytes accessed", 0.0))}
    except Exception as e:  # pragma: no cover
        rec["xla_cost_raw"] = {"error": str(e)}
    hlo = compiled.as_text()
    dump = os.environ.get("REPRO_DUMP_HLO")
    if dump:
        with open(dump, "w") as f:
            f.write(hlo)
    from repro.launch.hlo_analysis import analyze_hlo
    an = analyze_hlo(hlo)            # trip-count-aware, per-device
    rec["analysis"] = {
        "flops_per_device": an["flops"],
        "hbm_bytes_per_device": an["hbm_bytes"],
        "collective_bytes_per_device": an["collective_bytes"],
        "collectives": an["collectives"],
    }

    # ---- roofline terms (per-device) ------------------------------------
    chips = 512 if multi_pod else 256
    pc = cfg.param_counts()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    model_flops = 6.0 * pc["active"] * tokens if shape.kind == "train" \
        else 2.0 * pc["active"] * tokens
    hlo_flops_global = an["flops"] * chips
    rec["roofline"] = {
        "chips": chips,
        "compute_s": an["flops"] / PEAK_FLOPS,
        "memory_s": an["hbm_bytes"] / HBM_BW,
        "collective_s": an["collective_bytes"] / ICI_BW,
        "model_flops": model_flops,
        "hlo_flops_global": hlo_flops_global,
        "useful_ratio": (model_flops / hlo_flops_global
                         if hlo_flops_global else 0.0),
    }
    terms = {k: rec["roofline"][k]
             for k in ("compute_s", "memory_s", "collective_s")}
    rec["roofline"]["bottleneck"] = max(terms, key=terms.get)
    rec["status"] = "ok"
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-compile", action="store_true")
    args = ap.parse_args()

    from repro.configs import ASSIGNED
    archs = ASSIGNED if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                try:
                    rec = lower_one(arch, shape, mp,
                                    compile_=not args.no_compile)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "FAILED", "error": repr(e)}
                    n_fail += 1
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=2)
                status = rec.get("status")
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" compute={r['compute_s']*1e3:.2f}ms "
                             f"mem={r['memory_s']*1e3:.2f}ms "
                             f"coll={r['collective_s']*1e3:.2f}ms "
                             f"bound={r['bottleneck'].split('_')[0]} "
                             f"useful={r['useful_ratio']:.2f}")
                elif status == "FAILED":
                    extra = " " + rec.get("error", "")[:120]
                elif status == "skipped":
                    extra = " " + rec.get("reason", "")[:80]
                print(f"[{status:>7s}] {tag}{extra}", flush=True)
    if n_fail:
        raise SystemExit(f"{n_fail} combinations failed")
    print("dry-run complete")


if __name__ == "__main__":
    main()
