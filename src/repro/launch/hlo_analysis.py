"""Trip-count-aware HLO cost analysis for the dry-run roofline.

XLA's built-in ``compiled.cost_analysis()`` visits each while-loop body ONCE,
so a scan-over-layers model under-reports FLOPs by ~num_layers× (and the
flash-attention KV scan by another Skv/block×). This module re-derives the
three roofline inputs directly from ``compiled.as_text()``:

  flops            — Σ dot-op FLOPs × effective loop multiplier
  hbm_bytes        — Σ output bytes of MATERIALIZED ops (top-level ops in
                     traversed computations; fusion internals excluded) ×
                     multiplier + entry parameter bytes  (HBM-traffic proxy)
  collective_bytes — Σ output bytes of all-reduce / all-gather /
                     reduce-scatter / all-to-all / collective-permute ×
                     multiplier (per-device view; ring-transfer ≈ output size)

Loop trip counts are recovered from each while-condition's
``compare(iv, constant(N))``; nested loops multiply. All quantities are
PER-DEVICE (the SPMD program is one device's program).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
}
_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(shape_str: str) -> float:
    total = 0.0
    for m in _SHAPE_RE.finditer(shape_str):
        b = _DTYPE_BYTES[m.group(1)]
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n * b
    return total


def _shape_dims(shape_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


@dataclasses.dataclass
class Op:
    name: str
    shape_str: str
    opcode: str
    rest: str          # operands + attrs (raw tail of the line)


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    ops: List[Op] = dataclasses.field(default_factory=list)
    shapes: Dict[str, str] = dataclasses.field(default_factory=dict)


def parse_computations(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if line and not line[0].isspace():
            m = _COMP_RE.match(line)
            if m:
                cur = Computation(m.group(1),
                                  line.lstrip().startswith("ENTRY"))
                comps[cur.name] = cur
                continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if m:
            op = Op(m.group(1), m.group(2), m.group(3), m.group(4))
            cur.ops.append(op)
            cur.shapes[op.name] = op.shape_str
        else:
            # parameters: "  %param.1 = f32[2,3]{...} parameter(0)" matched
            # above; tuple-only lines ignored
            pass
    return comps


def _operand_names(rest: str) -> List[str]:
    # operands are %refs before the closing paren of the op call
    depth, i, out = 1, 0, []
    while i < len(rest) and depth > 0:
        c = rest[i]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
        i += 1
    call = rest[: i - 1] if depth == 0 else rest
    return re.findall(r"%([\w\.\-]+)", call)


def _dot_flops(op: Op, comp: Computation,
               global_shapes: Dict[str, str]) -> float:
    out = _shape_dims(op.shape_str)
    if out is None:
        return 0.0
    _, out_dims = out
    operands = _operand_names(op.rest)
    if not operands:
        return 0.0
    lhs_shape_str = comp.shapes.get(operands[0]) or \
        global_shapes.get(operands[0])
    if lhs_shape_str is None:
        return 0.0
    lhs = _shape_dims(lhs_shape_str)
    if lhs is None:
        return 0.0
    _, lhs_dims = lhs
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    contract = [int(d) for d in m.group(1).split(",") if d] if m else []
    k = 1
    for d in contract:
        if d < len(lhs_dims):
            k *= lhs_dims[d]
    n_out = 1
    for d in out_dims:
        n_out *= d
    return 2.0 * n_out * k


def _while_edges(op: Op) -> Optional[Tuple[str, str]]:
    mb = re.search(r"body=%?([\w\.\-]+)", op.rest)
    mc = re.search(r"condition=%?([\w\.\-]+)", op.rest)
    if mb and mc:
        return mb.group(1), mc.group(1)
    return None


def _trip_count(cond: Computation) -> int:
    """Look for compare(..., constant(N)) in the condition computation."""
    consts: Dict[str, int] = {}
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.match(r"^(\d+)\)", op.rest)
            if m:
                consts[op.name] = int(m.group(1))
    for op in cond.ops:
        if op.opcode == "compare":
            for name in _operand_names(op.rest):
                if name in consts:
                    return max(consts[name], 1)
    # constants can be folded into fusions; fall back to any int constant
    if consts:
        return max(consts.values())
    return 1


_TRAVERSE_OPCODES = {"call", "conditional", "async-start"}

# Ops whose output would be FUSED into a neighbor on the TPU backend —
# excluded from the HBM-traffic proxy (the CPU backend materializes them as
# separate top-level ops, which would wildly overstate TPU traffic).
_FUSABLE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "logistic", "rsqrt", "sqrt", "power", "negate", "abs", "compare",
    "select", "and", "or", "not", "xor", "convert", "broadcast", "iota",
    "reshape", "bitcast", "constant", "parameter", "get-tuple-element",
    "tuple", "clamp", "sign", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "cosine", "sine", "reduce-precision", "is-finite",
    "shift-left", "shift-right-logical", "shift-right-arithmetic", "rem",
    "bitcast-convert", "optimization-barrier", "after-all", "copy-start",
    "copy-done", "partition-id", "replica-id", "rng-bit-generator",
}


def analyze_hlo(text: str) -> Dict:
    comps = parse_computations(text)
    global_shapes: Dict[str, str] = {}
    for c in comps.values():
        global_shapes.update(c.shapes)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return {"flops": 0.0, "hbm_bytes": 0.0, "collective_bytes": 0.0,
                "collectives": {}}

    # ---- effective multipliers over the call graph --------------------
    mult: Dict[str, float] = {entry.name: 1.0}
    byte_visible: Dict[str, bool] = {entry.name: True}
    local_trip: Dict[str, int] = {entry.name: 1}
    order = [entry.name]
    seen = {entry.name}
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        for op in comp.ops:
            if op.opcode == "while":
                e = _while_edges(op)
                if not e:
                    continue
                body, cond = e
                n = _trip_count(comps[cond]) if cond in comps else 1
                for tgt, k, vis in ((body, m * n, byte_visible[cname]),
                                    (cond, m * n, False)):
                    mult[tgt] = mult.get(tgt, 0.0) + k
                    byte_visible[tgt] = byte_visible.get(tgt, False) or vis
                    local_trip[tgt] = max(local_trip.get(tgt, 1), n)
                    if tgt not in seen:
                        seen.add(tgt)
                        order.append(tgt)
            else:
                for attr in ("calls", "body", "to_apply", "branch_computations"):
                    for mm in re.finditer(attr + r"=\{?%?([\w\.\-]+)", op.rest):
                        tgt = mm.group(1)
                        if tgt not in comps:
                            continue
                        vis = (byte_visible[cname]
                               and op.opcode in _TRAVERSE_OPCODES)
                        mult[tgt] = mult.get(tgt, 0.0) + m
                        byte_visible[tgt] = byte_visible.get(tgt, False) or vis
                        if tgt not in seen:
                            seen.add(tgt)
                            order.append(tgt)

    # ---- accumulate costs ---------------------------------------------
    flops = 0.0
    hbm = 0.0
    coll_bytes: Dict[str, float] = {c: 0.0 for c in COLLECTIVES}
    coll_counts: Dict[str, float] = {c: 0.0 for c in COLLECTIVES}
    for cname in order:
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        vis = byte_visible.get(cname, False)
        for op in comp.ops:
            if op.opcode == "dot" or op.opcode == "convolution":
                flops += m * _dot_flops(op, comp, global_shapes)
            base = op.opcode.replace("-start", "")
            if base in COLLECTIVES and not op.opcode.endswith("-done"):
                b = _shape_bytes(op.shape_str)
                coll_bytes[base] += m * b
                coll_counts[base] += m
            if vis and op.opcode not in _FUSABLE and \
                    op.opcode not in ("while", "conditional") and \
                    base not in COLLECTIVES:
                b = _shape_bytes(op.shape_str)
                if "dynamic-update-slice" in op.opcode or \
                        "dynamic-update-slice" in op.name:
                    # in-place slice write into a (stacked) buffer: actual
                    # traffic is one slice, not the whole aliased buffer
                    if op.opcode == "dynamic-update-slice":
                        ops_ = _operand_names(op.rest)
                        upd = (comp.shapes.get(ops_[1])
                               or global_shapes.get(ops_[1])) if \
                            len(ops_) > 1 else None
                        b = _shape_bytes(upd) if upd else \
                            b / max(local_trip.get(cname, 1), 1)
                    else:
                        b = b / max(local_trip.get(cname, 1), 1)
                hbm += m * b
                if op.opcode in ("dot", "convolution"):
                    # matmuls read their operands from HBM
                    for oname in _operand_names(op.rest)[:2]:
                        s = comp.shapes.get(oname) or global_shapes.get(oname)
                        if s:
                            hbm += m * _shape_bytes(s)
    # entry parameters are read from HBM once
    for op in entry.ops:
        if op.opcode == "parameter":
            hbm += _shape_bytes(op.shape_str)

    return {
        "flops": flops,
        "hbm_bytes": hbm,
        "collective_bytes": sum(coll_bytes.values()),
        "collectives": {"bytes_by_op": coll_bytes, "counts": coll_counts},
    }
