"""Sharding rules: PartitionSpec pytrees for params / caches / batches.

DP+EP mapping on the production mesh (DESIGN.md §3):
  - "model"          TP: attention heads, FFN hidden, expert dim (EP)
  - "data" (+"pod")  DP: batch; FSDP for params/optimizer when enabled;
                     sequence for the long-context decode shape
  - experts          sharded over `expert_axes` (("model",) by default;
                     ("data","model") for DeepSeek-V3's 256 experts ⇒ exactly
                     1 expert/chip on a 256-chip pod)

Rules are divisibility-guarded: a dim is sharded only if it divides evenly by
the axis size; otherwise the next candidate axis (or replication) is used —
e.g. whisper's 20 heads don't divide 16, so its attention projections fall
back to d_model (row-parallel) sharding.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config.base import (
    AttentionKind, LayerKind, ModelConfig, ParallelConfig,
)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    n = _axis_size(mesh, axes)
    return n > 0 and dim % n == 0


class ShardingRules:
    """Resolves per-leaf PartitionSpecs for one (cfg, mesh, parallel)."""

    def __init__(self, cfg: ModelConfig, mesh: Mesh, par: ParallelConfig):
        self.cfg = cfg
        self.mesh = mesh
        self.par = par
        self.model = par.model_axis if par.model_axis in mesh.axis_names else None
        self.data: Tuple[str, ...] = tuple(
            a for a in par.data_axes if a in mesh.axis_names)
        if "pod" in mesh.axis_names and "pod" not in self.data:
            self.data = ("pod",) + self.data
        self.fsdp: Optional[Tuple[str, ...]] = (
            tuple(a for a in par.fsdp_axes if a in mesh.axis_names)
            if par.fsdp_params else None)
        self.experts = tuple(a for a in par.expert_axes
                             if a in mesh.axis_names) or (self.model,)

    # -- helpers --------------------------------------------------------
    def _maybe(self, dim: int, axes):
        """axes if divisible else None."""
        if axes is None:
            return None
        return axes if _fits(dim, self.mesh, axes) else None

    def _fsdp_dim(self, shape, taken: Sequence[Optional[object]]):
        """Pick the largest remaining dim divisible by the fsdp axes —
        skipped entirely if any fsdp axis is already used by another dim."""
        if not self.fsdp:
            return None
        used = set()
        for t in taken:
            if t is None:
                continue
            used.update((t,) if isinstance(t, str) else tuple(t))
        if used & set(self.fsdp):
            return None
        best = None
        for i, d in enumerate(shape):
            if taken[i] is not None:
                continue
            if _fits(d, self.mesh, self.fsdp):
                if best is None or d > shape[best]:
                    best = i
        return best

    def matrix(self, shape, model_dim: Optional[int]) -> P:
        """Generic 2-D+ weight: try model on `model_dim`, fsdp on the largest
        other dim."""
        spec: list = [None] * len(shape)
        if model_dim is not None and self.model and \
                _fits(shape[model_dim], self.mesh, self.model):
            spec[model_dim] = self.model
        i = self._fsdp_dim(shape, spec)
        if i is not None:
            spec[i] = self.fsdp
        return P(*spec)

    def expert_matrix(self, shape) -> P:
        """(E, ..., ...): expert dim on expert_axes; fsdp on the largest
        remaining dim."""
        spec: list = [None] * len(shape)
        ex = self.experts
        if ex and _fits(shape[0], self.mesh, ex):
            spec[0] = ex if len(ex) > 1 else ex[0]
        elif self.model and _fits(shape[0], self.mesh, self.model):
            spec[0] = self.model
        i = self._fsdp_dim(shape, spec)
        if i is not None:
            spec[i] = self.fsdp
        return P(*spec)

    def replicated(self, shape) -> P:
        return P(*([None] * len(shape)))


# ---------------------------------------------------------------------------
# Param specs (path-pattern based)
# ---------------------------------------------------------------------------

def _leaf_spec(rules: ShardingRules, path: Tuple[str, ...], leaf) -> P:
    shape = leaf.shape
    name = path[-1]
    stacked = 1 if (len(path) >= 2 and path[0] in
                    ("prefix", "blocks", "encoder")) else 0
    # `stacked` leading layer axis is never sharded

    def off(spec: P) -> P:
        if stacked:
            return P(*((None,) * stacked + tuple(spec)))
        return spec

    core = shape[stacked:]
    r = rules
    if name in ("embed",):                       # (V, D)
        if _fits(core[0], r.mesh, r.model):
            return off(r.matrix(core, 0))
        return off(r.matrix(core, 1))
    if name in ("lm_head",):                     # (D, V)
        if _fits(core[1], r.mesh, r.model):
            return off(r.matrix(core, 1))
        return off(r.matrix(core, 0))
    if name in ("w_q", "w_k", "w_v"):            # (D, H|K, hd)
        if _fits(core[1], r.mesh, r.model):
            return off(r.matrix(core, 1))
        return off(r.matrix(core, 0))            # row-parallel fallback
    if name == "w_o":                            # (H, hd, D)
        if _fits(core[0], r.mesh, r.model):
            return off(r.matrix(core, 0))
        return off(r.matrix(core, 2))
    if name in ("w_uq", "w_uk", "w_uv"):         # (r|qin, H, dn)
        if _fits(core[1], r.mesh, r.model):
            return off(r.matrix(core, 1))
        return off(r.matrix(core, None))
    if name in ("w_dq", "w_dkv", "w_kr", "proj"):
        return off(r.matrix(core, None))
    if name in ("w_gate", "w_up"):               # dense (D, ff) OR moe (E,D,f)
        if len(core) == 3:
            return off(r.expert_matrix(core))
        return off(r.matrix(core, 1))
    if name == "w_down":                         # (ff, D) OR (E, f, D)
        if len(core) == 3:
            return off(r.expert_matrix(core))
        return off(r.matrix(core, 0))
    if name == "router":                         # (D, E) — replicated f32
        return off(r.matrix(core, None))
    if name in ("shared_gate", "shared_up"):     # (D, f·n)
        return off(r.matrix(core, 1))
    if name == "shared_down":                    # (f·n, D)
        return off(r.matrix(core, 0))
    if name in ("w_zx", "w_dt"):                 # mamba (D, 2di) / (D, nh)
        return off(r.matrix(core, 1) if _fits(core[1], r.mesh, r.model)
                   else r.matrix(core, None))
    if name == "w_bc":                           # (D, 2·g·ds) — tiny
        return off(r.matrix(core, None))
    if name == "out_proj":                       # (di, D)
        return off(r.matrix(core, 0) if _fits(core[0], r.mesh, r.model)
                   else r.matrix(core, None))
    if name == "conv_wx":                        # (dconv, di) depthwise
        return off(P(*([None] * (len(core) - 1)),
                     r.model if _fits(core[-1], r.mesh, r.model) else None))
    if name == "conv_bx":
        return off(P(*([None] * (len(core) - 1)),
                     r.model if _fits(core[-1], r.mesh, r.model) else None)
                   if len(core) >= 1 else r.replicated(core))
    if name in ("conv_wbc", "conv_bbc"):
        return off(r.replicated(core))
    # norms, biases, A_log, D_skip, dt_bias, router_bias, scalars
    return off(r.replicated(core))


def param_pspecs(cfg: ModelConfig, mesh: Mesh, par: ParallelConfig, params):
    rules = ShardingRules(cfg, mesh, par)

    def visit(path, leaf):
        keys = tuple(_key_str(p) for p in path)
        return _leaf_spec(rules, keys, leaf)

    return jax.tree_util.tree_map_with_path(visit, params)


def _key_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    return str(p)


# ---------------------------------------------------------------------------
# Cache / batch specs
# ---------------------------------------------------------------------------

def cache_pspecs(cfg: ModelConfig, mesh: Mesh, par: ParallelConfig, cache,
                 batch_size: int):
    """Decode-cache sharding. Batch on data axes when divisible; otherwise
    (long_500k, B=1) the sequence axis is sharded on data. KV-head dim on
    model when divisible, else the sequence axis goes on model (MLA latent)."""
    rules = ShardingRules(cfg, mesh, par)
    data = rules.data
    model = rules.model
    b_on_data = _fits(batch_size, mesh, data)

    def leaf(path, x):
        keys = [_key_str(p) for p in path]
        shape = x.shape
        if keys[0] in ("cur",):
            return P(data) if b_on_data else P(None)
        if keys[0] == "kv_pos":
            if b_on_data:
                return P(data, None)
            return P(None, data) if _fits(shape[1], mesh, data) else P(None, None)
        # stacked entries: (R, B, ...) — R never sharded
        spec = [None] * len(shape)
        if b_on_data and len(shape) >= 2:
            spec[1] = data
        if len(shape) == 5:          # (R,B,S,K,hd) attention KV
            if model and _fits(shape[3], mesh, model):
                spec[3] = model
            elif model and _fits(shape[2], mesh, model):
                spec[2] = model
            if not b_on_data and _fits(shape[2], mesh, data) and spec[2] is None:
                spec[2] = data
        elif len(shape) == 4 and keys[-1] != "kv_pos":
            # (R,B,S,r) MLA latent / rope cache  OR (R,B,nh,hp) …
            if not b_on_data and _fits(shape[2], mesh, data):
                spec[2] = data
            elif model and _fits(shape[2], mesh, model) and shape[2] >= 256:
                spec[2] = model
        elif len(shape) == 3:
            pass
        return P(*spec)

    return jax.tree_util.tree_map_with_path(leaf, cache)


def paged_cache_pspecs(cfg: ModelConfig, mesh: Mesh, par: ParallelConfig,
                       cache):
    """Sharding for a PAGED (block-table) decode cache — the layout the
    real serving plane keeps per deployment (models.model.init_paged_cache).

    The sharded real engines merge every DP unit's rows into ONE cache:
    slot s belongs to DP s // paged_slots and physical block b to DP
    b // paged_pool_blocks, so BOTH leading pool dims shard naturally on
    the data axes (DP d's rows live on mesh rank d) — that placement is
    what turns the per-step collective into a genuine cross-DP barrier.
    KV heads of attention pools go on the model axis when divisible.
    Every rule is divisibility-guarded: a non-dividing dim replicates,
    so the same function serves the (smaller, possibly non-dividing)
    prefill-engine cache.  Works on concrete arrays, ShapeDtypeStructs,
    or tracers — only `.shape` is read."""
    rules = ShardingRules(cfg, mesh, par)
    data = rules.data
    model = rules.model
    slots = cache["cur"].shape[0]
    nblocks = cache["kv_pos"].shape[0]
    s_ax = data if _fits(slots, mesh, data) else None
    b_ax = data if _fits(nblocks, mesh, data) else None

    def leaf(path, x):
        keys = [_key_str(p) for p in path]
        shape = x.shape
        if keys[0] == "cur":
            return P(s_ax)
        if keys[0] == "kv_pos":
            return P(b_ax, None)
        if keys[0] == "block_tab":
            return P(s_ax, None)
        # group entries: (n, N_blocks, bs, ...) attention pools, or
        # (n, slots, ...) per-slot entries (SSM state, enc-dec KV)
        spec: list = [None] * len(shape)
        if len(shape) >= 2:
            if shape[1] == nblocks:
                spec[1] = b_ax
            elif shape[1] == slots:
                spec[1] = s_ax
        if (len(shape) == 5 and shape[1] == nblocks and model
                and _fits(shape[3], mesh, model)):
            spec[3] = model          # (n, N, bs, K, hd): KV heads on TP
        return P(*spec)

    return jax.tree_util.tree_map_with_path(leaf, cache)


def data_axes_of(mesh: Mesh, par: ParallelConfig) -> Tuple[str, ...]:
    axes = tuple(a for a in par.data_axes if a in mesh.axis_names)
    if "pod" in mesh.axis_names and "pod" not in axes:
        axes = ("pod",) + axes
    return axes


def batch_pspecs(mesh: Mesh, par: ParallelConfig, batch_size: int,
                 tree) -> object:
    data = data_axes_of(mesh, par)
    b_on_data = _fits(batch_size, mesh, data)

    def leaf(path, x):
        shape = x.shape
        spec = [None] * len(shape)
        if b_on_data:
            spec[0] = data
        elif len(shape) >= 2 and _fits(shape[1], mesh, data):
            spec[1] = data          # shard sequence (long-context)
        return P(*spec)

    return jax.tree_util.tree_map_with_path(leaf, tree)


def opt_pspecs(param_specs):
    """Optimizer moments mirror the param specs; step is replicated."""
    return {
        "mu": param_specs,
        "nu": param_specs,
        "step": P(),
    }


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree, is_leaf=lambda x: isinstance(x, P))
