from repro.distributed.sharding import (
    param_pspecs, cache_pspecs, batch_pspecs, opt_pspecs, named,
)

__all__ = ["param_pspecs", "cache_pspecs", "batch_pspecs", "opt_pspecs",
           "named"]
