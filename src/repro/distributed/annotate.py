"""Logical sharding annotations for model code.

Model code is mesh-agnostic; launchers activate a mesh + logical-axis map
(contextvar), and `constrain(x, *logical_axes)` becomes a
`with_sharding_constraint` resolving logical names ("tokens", "experts",
"model", "ffn", …) to mesh axes. Outside an activation it is a no-op, so
tests and CPU paths are untouched.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "repro_mesh_ctx", default=None)


@contextlib.contextmanager
def activate(mesh: Mesh, axis_map: Dict[str, Union[str, Tuple[str, ...]]],
             ep_shard_map: bool = False):
    """axis_map: logical name -> mesh axis (or tuple of axes).
    ep_shard_map=True routes MoE blocks through the explicit all-to-all
    shard_map path (repro.models.moe_ep) where applicable."""
    token = _CTX.set({"mesh": mesh, "map": dict(axis_map),
                      "ep": ep_shard_map})
    try:
        yield
    finally:
        _CTX.reset(token)


def active() -> Optional[Dict]:
    return _CTX.get()


def constrain(x, *logical_axes):
    """Annotate `x` with the resolved PartitionSpec; no-op without a mesh.
    Each entry is a logical axis name, None, or a tuple of names."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    amap = ctx["map"]
    mesh = ctx["mesh"]

    def resolve(a):
        if a is None:
            return None
        if isinstance(a, (tuple, list)):
            out = []
            for e in a:
                r = amap.get(e)
                if r is None:
                    continue
                out.extend((r,) if isinstance(r, str) else tuple(r))
            return tuple(out) or None
        r = amap.get(a)
        return r

    spec = P(*[resolve(a) for a in logical_axes])
    # divisibility guard: skip annotation if any dim doesn't divide
    import numpy as np
    for dim, ax in zip(x.shape, spec):
        if ax is None:
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        n = int(np.prod([mesh.shape[a] for a in axes]))
        if dim % n != 0:
            return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
