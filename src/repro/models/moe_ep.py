"""Expert-parallel MoE via shard_map + explicit all-to-all (§Perf iter. 2).

This is the TPU-native analogue of the DeepSeek-V3 production EP dispatch
the paper's cluster runs: each device routes its token slice into per-expert
capacity buckets, a pair of all-to-alls moves only the routed token rows
(≈ T·k·D bytes globally, vs. GSPMD's replicated-gather all-reduces measured
at 240 GB f32 per layer), experts compute locally, and the combine is a
local gather.

Semantics note: capacity is enforced PER SOURCE RANK (C_dev each), like real
EP systems — the drop pattern differs slightly from the single-program
moe_block under overload; with a non-binding capacity factor the outputs
match exactly (tested).
"""
from __future__ import annotations

import functools
import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.config.base import MoEConfig
from repro.models.moe import aux_loss, route


def _local_dispatch(x_loc, top_w, top_e, E: int, C: int):
    """Bucket the local token slice by expert. Returns (buckets (E,C,D),
    routing table back-refs)."""
    T, D = x_loc.shape
    k = top_e.shape[-1]
    flat_e = top_e.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    pos_in_e = jnp.arange(T * k) - first[sorted_e]
    keep = pos_in_e < C
    c_idx = jnp.where(keep, pos_in_e, C)
    tok = order // k
    tok_buf = jnp.full((E, C + 1), T, jnp.int32).at[sorted_e, c_idx].set(
        jnp.where(keep, tok, T))
    x_pad = jnp.concatenate([x_loc, jnp.zeros((1, D), x_loc.dtype)])
    buckets = x_pad[tok_buf[:, :C]]                   # (E, C, D)
    pos_tk = jnp.zeros((T * k,), jnp.int32).at[order].set(c_idx).reshape(T, k)
    return buckets, pos_tk


def moe_block_ep(x: jnp.ndarray, params: Dict, mc: MoEConfig, mesh,
                 token_axes: Tuple[str, ...], ep_axes: Tuple[str, ...],
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """EP MoE with explicit all-to-all. x: (B, S, D) sharded tokens@token_axes.

    Requires E % G_ep == 0 where G_ep = prod(mesh[a] for a in ep_axes).
    Non-EP axes of the mesh replicate the expert weights.
    """
    orig_shape = x.shape
    x2d = x.reshape(-1, x.shape[-1])
    T, D = x2d.shape
    E, k = mc.num_experts, mc.top_k
    import numpy as np
    G = int(np.prod([mesh.shape[a] for a in ep_axes]))
    E_per = E // G
    n_tok_shards = int(np.prod([mesh.shape[a] for a in token_axes]))
    # token slice per device = T / (all mesh axes), since every axis either
    # shards tokens or splits the replicated copy
    all_axes = tuple(mesh.axis_names)
    n_dev = int(np.prod([mesh.shape[a] for a in all_axes]))
    T_loc = T // n_dev
    C_dev = max(int(math.ceil(T_loc * k / E * mc.capacity_factor)), 1)

    other_axes = tuple(a for a in all_axes if a not in token_axes)

    def body(x_blk, router_p, w_gate, w_up, w_down, bias):
        # x_blk: (T/n_tok_shards, D) — replicated over other_axes; take the
        # slice this device owns along the replicated axes.
        n_rep = int(np.prod([mesh.shape[a] for a in other_axes])) or 1
        Tb = x_blk.shape[0]
        if n_rep > 1:
            idx = jax.lax.axis_index(other_axes)
            x_loc = jax.lax.dynamic_slice_in_dim(
                x_blk, idx * (Tb // n_rep), Tb // n_rep, axis=0)
        else:
            x_loc = x_blk
        rp = {"router": router_p}
        if bias is not None:
            rp["router_bias"] = bias
        top_w, top_e, probs = route(x_loc, rp, mc)
        laux = aux_loss(probs, top_e, E)
        laux = jax.lax.pmean(laux, all_axes)

        buckets, pos_tk = _local_dispatch(x_loc, top_w, top_e, E, C_dev)
        # Dispatch all-to-all convention (both a2a calls in this body):
        # tiled=True on a (G, E_per·C, D) operand SPLITS axis 0 across the
        # EP group (slice g goes to rank g) and CONCATS the received
        # slices back on axis 0 — so post-a2a axis 0 indexes the SOURCE
        # rank, and slice s holds the E_per local experts' capacity rows
        # that rank s routed to this device.  The combine a2a below is the
        # exact inverse (same split/concat axis ⇒ self-inverse).
        b = buckets.reshape(G, E_per * C_dev, D)
        b = jax.lax.all_to_all(b, ep_axes, split_axis=0, concat_axis=0,
                               tiled=True)
        h = b.reshape(G, E_per, C_dev, D).transpose(1, 0, 2, 3)
        h = h.reshape(E_per, G * C_dev, D)
        g = jnp.einsum("ecd,edf->ecf", h, w_gate)
        u = jnp.einsum("ecd,edf->ecf", h, w_up)
        act = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * u
        y = jnp.einsum("ecf,efd->ecd", act, w_down)   # (E_per, G·C, D)
        y = y.reshape(E_per, G, C_dev, D).transpose(1, 0, 2, 3)
        y = y.reshape(G, E_per * C_dev, D)
        y = jax.lax.all_to_all(y, ep_axes, split_axis=0, concat_axis=0,
                               tiled=True)
        y = y.reshape(E, C_dev, D)
        y_pad = jnp.concatenate([y, jnp.zeros((E, 1, D), y.dtype)], axis=1)
        contrib = y_pad[top_e, pos_tk]                # (T_loc, k, D)
        out_loc = (contrib * top_w[..., None].astype(y.dtype)).sum(axis=1)
        # reassemble the replicated block: all_gather over other_axes
        if n_rep > 1:
            out = jax.lax.all_gather(out_loc, other_axes, axis=0, tiled=True)
        else:
            out = out_loc
        return out, laux

    tok_spec = P(token_axes if token_axes else None, None)
    w_spec = P(ep_axes if len(ep_axes) > 1 else ep_axes[0], None, None)
    bias = params.get("router_bias")
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(tok_spec, P(None, None), w_spec, w_spec, w_spec,
                  P(None) if bias is not None else None),
        out_specs=(tok_spec, P()),
        check_rep=False)
    out, laux = fn(x2d, params["router"], params["w_gate"], params["w_up"],
                   params["w_down"], bias)

    if mc.num_shared:
        gs = jnp.einsum("td,df->tf", x2d, params["shared_gate"])
        us = jnp.einsum("td,df->tf", x2d, params["shared_up"])
        hs = jax.nn.silu(gs.astype(jnp.float32)).astype(x2d.dtype) * us
        out = out + jnp.einsum("tf,fd->td", hs, params["shared_down"])
    return out.reshape(orig_shape), laux
