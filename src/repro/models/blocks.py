"""Transformer blocks: one (mixer + FFN) block per LayerKind.

kind DENSE   = attention + dense SwiGLU
kind MOE     = attention + MoE
kind SSM     = Mamba2 mixer + dense SwiGLU (or nothing when d_ff == 0)
kind SSM_MOE = Mamba2 mixer + MoE           (jamba)

Each block has a full-sequence path (train / prefill, optionally emitting the
cache entry) and a decode path (single token against the cache entry).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import AttentionKind, LayerKind, ModelConfig
from repro.models import attention as A
from repro.models.layers import init_linear, rms_norm, swiglu, apply_rope
from repro.models.mamba import (
    init_mamba_params, mamba_forward, mamba_decode_step, ssm_dims,
)
from repro.models.moe import init_moe_params, moe_block


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_attn_params(key, cfg: ModelConfig, dtype, cross: bool = False) -> Dict:
    D = cfg.d_model
    hd = cfg.resolved_head_dim
    H, K = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 8)
    if cfg.attention == AttentionKind.MLA and not cross:
        m = cfg.mla
        p: Dict = {}
        q_in = D
        if m.q_lora_rank:
            p["w_dq"] = init_linear(ks[0], D, m.q_lora_rank, dtype)
            p["q_norm"] = jnp.ones((m.q_lora_rank,), dtype)
            q_in = m.q_lora_rank
        p["w_uq"] = init_linear(
            ks[1], q_in, H * (m.qk_nope_head_dim + m.qk_rope_head_dim), dtype
        ).reshape(q_in, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
        p["w_dkv"] = init_linear(ks[2], D, m.kv_lora_rank, dtype)
        p["kv_norm"] = jnp.ones((m.kv_lora_rank,), dtype)
        p["w_kr"] = init_linear(ks[3], D, m.qk_rope_head_dim, dtype)
        p["w_uk"] = (jax.random.normal(
            ks[4], (m.kv_lora_rank, H, m.qk_nope_head_dim), jnp.float32)
            / math.sqrt(m.kv_lora_rank)).astype(dtype)
        p["w_uv"] = (jax.random.normal(
            ks[5], (m.kv_lora_rank, H, m.v_head_dim), jnp.float32)
            / math.sqrt(m.kv_lora_rank)).astype(dtype)
        p["w_o"] = init_linear(ks[6], H * m.v_head_dim, D, dtype
                               ).reshape(H, m.v_head_dim, D)
        return p
    return {
        "w_q": init_linear(ks[0], D, H * hd, dtype).reshape(D, H, hd),
        "w_k": init_linear(ks[1], D, K * hd, dtype).reshape(D, K, hd),
        "w_v": init_linear(ks[2], D, K * hd, dtype).reshape(D, K, hd),
        "w_o": init_linear(ks[3], H * hd, D, dtype).reshape(H, hd, D),
    }


def init_dense_mlp_params(key, cfg: ModelConfig, dtype) -> Dict:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": init_linear(ks[0], cfg.d_model, cfg.d_ff, dtype),
        "w_up": init_linear(ks[1], cfg.d_model, cfg.d_ff, dtype),
        "w_down": init_linear(ks[2], cfg.d_ff, cfg.d_model, dtype),
    }


def init_block_params(key, cfg: ModelConfig, kind: LayerKind, dtype,
                      cross: bool = False, is_encoder: bool = False) -> Dict:
    ks = jax.random.split(key, 5)
    D = cfg.d_model
    p: Dict = {"ln1": jnp.ones((D,), dtype)}
    if kind in (LayerKind.DENSE, LayerKind.MOE):
        p["attn"] = init_attn_params(ks[0], cfg, dtype)
    else:
        p["mamba"] = init_mamba_params(ks[0], D, cfg.ssm, dtype)
    if cross:
        p["ln_x"] = jnp.ones((D,), dtype)
        p["xattn"] = init_attn_params(ks[1], cfg, dtype, cross=True)
    # FFN
    if kind in (LayerKind.MOE, LayerKind.SSM_MOE) and cfg.moe.num_experts:
        p["ln2"] = jnp.ones((D,), dtype)
        p["moe"] = init_moe_params(ks[2], D, cfg.moe, dtype)
    elif cfg.d_ff > 0:
        p["ln2"] = jnp.ones((D,), dtype)
        p["mlp"] = init_dense_mlp_params(ks[2], cfg, dtype)
    return p


# ---------------------------------------------------------------------------
# Attention sub-layer
# ---------------------------------------------------------------------------

FLASH_THRESHOLD = 2048  # use online-softmax scan beyond this KV length
FLASH_BLOCK = 512


def _qkv_full(p, x, cfg: ModelConfig, positions, use_rope=True):
    B, S, D = x.shape
    hd = cfg.resolved_head_dim
    H, K = cfg.num_heads, cfg.num_kv_heads
    q = jnp.einsum("bsd,dhe->bshe", x, p["w_q"])
    k = jnp.einsum("bsd,dhe->bshe", x, p["w_k"])
    v = jnp.einsum("bsd,dhe->bshe", x, p["w_v"])
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_full(p, x, cfg: ModelConfig, positions, seg=None,
              causal=True, use_rope=True):
    """Full-sequence self attention. Returns (out, (k, v)) for the cache."""
    B, S, _ = x.shape
    window = cfg.sliding_window if cfg.attention == AttentionKind.SWA else 0
    q, k, v = _qkv_full(p, x, cfg, positions, use_rope)
    if S > FLASH_THRESHOLD:
        o = A.flash_attention_xla(q, k, v, positions, positions, seg, seg,
                                  causal=causal, window=window,
                                  block=FLASH_BLOCK, sorted_layout=causal)
    else:
        mask = A.build_mask(positions, positions, seg, seg, causal, window)
        o = A.gqa_reference(q, k, v, mask)
    out = jnp.einsum("bshe,hed->bsd", o, p["w_o"])
    return out, (k, v)


def cross_attn_full(p, x, enc_out, cfg: ModelConfig, enc_kv=None):
    """Cross attention (whisper decoder). No RoPE, full visibility."""
    B, S, D = x.shape
    hd = cfg.resolved_head_dim
    H, K = cfg.num_heads, cfg.num_kv_heads
    q = jnp.einsum("bsd,dhe->bshe", x, p["w_q"])
    if enc_kv is None:
        Se = enc_out.shape[1]
        k = jnp.einsum("bsd,dhe->bshe", enc_out, p["w_k"])
        v = jnp.einsum("bsd,dhe->bshe", enc_out, p["w_v"])
    else:
        k, v = enc_kv
        Se = k.shape[1]
    qpos = jnp.zeros((B, S), jnp.int32)
    kpos = jnp.zeros((B, Se), jnp.int32)
    mask = A.build_mask(qpos, kpos, causal=False)
    o = A.gqa_reference(q, k, v, mask)
    out = jnp.einsum("bshe,hed->bsd", o, p["w_o"])
    return out, (k, v)


def attn_decode(p, x, cfg: ModelConfig, k_cache, v_cache, kv_pos, pos):
    """Single-token decode; cache write handled by caller (returns new k,v)."""
    B, _, D = x.shape
    hd = cfg.resolved_head_dim
    H, K = cfg.num_heads, cfg.num_kv_heads
    window = cfg.sliding_window if cfg.attention == AttentionKind.SWA else 0
    q = jnp.einsum("bsd,dhe->bshe", x, p["w_q"])
    k = jnp.einsum("bsd,dhe->bshe", x, p["w_k"])
    v = jnp.einsum("bsd,dhe->bshe", x, p["w_v"])
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)
    # write into cache at ring (SWA) or linear position; for non-SWA caches
    # Sc == max_len so pos % Sc == pos.
    Sc = k_cache.shape[1]
    idx = pos % Sc
    bidx = jnp.arange(B)
    k_cache = k_cache.at[bidx, idx].set(k[:, 0])
    v_cache = v_cache.at[bidx, idx].set(v[:, 0])
    kv_pos = kv_pos.at[bidx, idx].set(pos)
    o = A.decode_attention(q, k_cache, v_cache, kv_pos, pos, window)
    out = jnp.einsum("bshe,hed->bsd", o, p["w_o"])
    return out, (k_cache, v_cache, kv_pos)


def _paged_write_site(block_tab, pos, block_size):
    """Physical (block, offset) of each row's current token.  Rows whose
    logical block is unset (inactive slots, or cur past the table) write
    into physical block 0 — the reserved null block — so they can keep
    stepping on garbage without touching live pages."""
    nbt = block_tab.shape[1]
    lb = jnp.clip(pos // block_size, 0, nbt - 1)
    phys = jnp.take_along_axis(block_tab, lb[:, None], axis=1)[:, 0]
    return jnp.maximum(phys, 0), pos % block_size


def attn_decode_paged(p, x, cfg: ModelConfig, k_pool, v_pool, kv_pos_pool,
                      block_tab, pos):
    """Single-token decode against a paged pool: scatter the new K/V into
    the row's current physical block, then block-gather attend.  Pools
    (N, bs, K, hd); kv_pos_pool (N, bs); block_tab (B, nbt); pos (B,)."""
    window = cfg.sliding_window if cfg.attention == AttentionKind.SWA else 0
    q = jnp.einsum("bsd,dhe->bshe", x, p["w_q"])
    k = jnp.einsum("bsd,dhe->bshe", x, p["w_k"])
    v = jnp.einsum("bsd,dhe->bshe", x, p["w_v"])
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)
    phys, off = _paged_write_site(block_tab, pos, k_pool.shape[1])
    k_pool = k_pool.at[phys, off].set(k[:, 0].astype(k_pool.dtype))
    v_pool = v_pool.at[phys, off].set(v[:, 0].astype(v_pool.dtype))
    kv_pos_pool = kv_pos_pool.at[phys, off].set(pos)
    o = A.decode_attention_paged(q, k_pool, v_pool, kv_pos_pool, block_tab,
                                 pos, window)
    out = jnp.einsum("bshe,hed->bsd", o, p["w_o"])
    return out, (k_pool, v_pool, kv_pos_pool)


def mla_decode_paged(p, x, cfg: ModelConfig, ckv_pool, kr_pool, kv_pos_pool,
                     block_tab, pos):
    """Absorbed-form MLA decode over a paged latent pool (ckv_pool
    (N, bs, r); kr_pool (N, bs, dr))."""
    m = cfg.mla
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    q_nope, q_rope = _mla_q(p, x, cfg, pos[:, None])
    q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]
    ckv = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dkv"]), p["kv_norm"],
                   cfg.norm_eps)[:, 0]
    kr = apply_rope(
        jnp.einsum("bsd,de->bse", x, p["w_kr"])[:, :, None, :],
        pos[:, None], cfg.rope_theta)[:, 0, 0]
    phys, off = _paged_write_site(block_tab, pos, ckv_pool.shape[1])
    ckv_pool = ckv_pool.at[phys, off].set(ckv.astype(ckv_pool.dtype))
    kr_pool = kr_pool.at[phys, off].set(kr.astype(kr_pool.dtype))
    kv_pos_pool = kv_pos_pool.at[phys, off].set(pos)
    ckv_g = A.gather_paged(ckv_pool, block_tab)
    kr_g = A.gather_paged(kr_pool, block_tab)
    kv_pos_g = A.gather_paged_pos(kv_pos_pool, block_tab)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope, p["w_uk"])
    pattn, _ = A.mla_scores_decode(
        (q_lat * scale).astype(ckv_g.dtype),
        (q_rope * scale).astype(kr_g.dtype),
        ckv_g, kr_g, kv_pos_g, pos)
    ctx = jnp.einsum("bhs,bsr->bhr", pattn.astype(ckv_g.dtype), ckv_g)
    o = jnp.einsum("bhr,rhe->bhe", ctx, p["w_uv"])
    out = jnp.einsum("bhe,hed->bd", o, p["w_o"])[:, None]
    return out, (ckv_pool, kr_pool, kv_pos_pool)


# ---------------------------------------------------------------------------
# MLA sub-layer
# ---------------------------------------------------------------------------

def _mla_q(p, x, cfg: ModelConfig, positions):
    m = cfg.mla
    B, S, D = x.shape
    H = cfg.num_heads
    h = x
    if m.q_lora_rank:
        h = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dq"]), p["q_norm"],
                     cfg.norm_eps)
    q = jnp.einsum("bsr,rhe->bshe", h, p["w_uq"])
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_full(p, x, cfg: ModelConfig, positions, seg=None):
    """Full-sequence MLA. Returns (out, (ckv, k_rope)) latent cache entries."""
    m = cfg.mla
    B, S, D = x.shape
    H = cfg.num_heads
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    ckv = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dkv"]), p["kv_norm"],
                   cfg.norm_eps)
    k_rope = apply_rope(
        jnp.einsum("bsd,de->bse", x, p["w_kr"])[:, :, None, :], positions,
        cfg.rope_theta)[:, :, 0]                                   # (B,S,dr)
    k_nope = jnp.einsum("bsr,rhe->bshe", ckv, p["w_uk"])
    v = jnp.einsum("bsr,rhe->bshe", ckv, p["w_uv"])
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, H, m.qk_rope_head_dim))], axis=-1)
    if S > FLASH_THRESHOLD:
        o = A.flash_attention_xla(q, k, v, positions, positions, seg, seg,
                                  causal=True, block=FLASH_BLOCK,
                                  sorted_layout=True)
    else:
        mask = A.build_mask(positions, positions, seg, seg, True, 0)
        o = A.gqa_reference(q, k, v, mask)
    out = jnp.einsum("bshe,hed->bsd", o, p["w_o"])
    return out, (ckv, k_rope)


def mla_decode(p, x, cfg: ModelConfig, ckv_cache, kr_cache, kv_pos, pos):
    """Absorbed-form MLA decode against the latent cache (no per-head K/V)."""
    m = cfg.mla
    B = x.shape[0]
    H = cfg.num_heads
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    q_nope, q_rope = _mla_q(p, x, cfg, pos[:, None])
    q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]            # (B,H,·)
    ckv = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dkv"]), p["kv_norm"],
                   cfg.norm_eps)[:, 0]                      # (B,r)
    kr = apply_rope(
        jnp.einsum("bsd,de->bse", x, p["w_kr"])[:, :, None, :],
        pos[:, None], cfg.rope_theta)[:, 0, 0]              # (B,dr)
    Sc = ckv_cache.shape[1]
    bidx = jnp.arange(B)
    idx = pos % Sc
    ckv_cache = ckv_cache.at[bidx, idx].set(ckv)
    kr_cache = kr_cache.at[bidx, idx].set(kr)
    kv_pos = kv_pos.at[bidx, idx].set(pos)
    # absorb W_uk into q
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope, p["w_uk"])
    pattn, _ = A.mla_scores_decode(
        (q_lat * scale).astype(ckv_cache.dtype),
        (q_rope * scale).astype(kr_cache.dtype),
        ckv_cache, kr_cache, kv_pos, pos)
    ctx = jnp.einsum("bhs,bsr->bhr", pattn.astype(ckv_cache.dtype), ckv_cache)
    o = jnp.einsum("bhr,rhe->bhe", ctx, p["w_uv"])
    out = jnp.einsum("bhe,hed->bd", o, p["w_o"])[:, None]
    return out, (ckv_cache, kr_cache, kv_pos)


# ---------------------------------------------------------------------------
# Chunk-extend attention (chunked prefill — the paper's C_chunk unit)
# ---------------------------------------------------------------------------

def attn_extend(p, x, cfg: ModelConfig, k_cache, v_cache, kv_pos, positions):
    """Multi-token extend: write the chunk's K/V into the cache, then attend
    q against the WHOLE cache with position masking (covers both history and
    intra-chunk causality in one pass)."""
    B, Sc, D = x.shape
    hd = cfg.resolved_head_dim
    H, K = cfg.num_heads, cfg.num_kv_heads
    window = cfg.sliding_window if cfg.attention == AttentionKind.SWA else 0
    q = jnp.einsum("bsd,dhe->bshe", x, p["w_q"])
    k = jnp.einsum("bsd,dhe->bshe", x, p["w_k"])
    v = jnp.einsum("bsd,dhe->bshe", x, p["w_v"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    S_buf = k_cache.shape[1]
    bidx = jnp.arange(B)[:, None]
    idx = positions % S_buf
    k_cache = k_cache.at[bidx, idx].set(k.astype(k_cache.dtype))
    v_cache = v_cache.at[bidx, idx].set(v.astype(v_cache.dtype))
    kv_pos = kv_pos.at[bidx, idx].set(positions)
    o = A.flash_attention_xla(
        q, k_cache, v_cache, positions, kv_pos,
        causal=True, window=window,
        block=min(FLASH_BLOCK, S_buf)) if S_buf > FLASH_THRESHOLD else None
    if o is None:
        mask = A.build_mask(positions, kv_pos, causal=True, window=window)
        mask &= (kv_pos >= 0)[:, None, :]
        o = A.gqa_reference(q, k_cache, v_cache, mask)
    out = jnp.einsum("bshe,hed->bsd", o, p["w_o"])
    return out, (k_cache, v_cache, kv_pos)


def mla_extend(p, x, cfg: ModelConfig, ckv_cache, kr_cache, kv_pos, positions):
    """Chunk extend for MLA in absorbed form (latent cache only)."""
    m = cfg.mla
    B, Sc, D = x.shape
    H = cfg.num_heads
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    q_nope, q_rope = _mla_q(p, x, cfg, positions)           # (B,Sc,H,·)
    ckv = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dkv"]), p["kv_norm"],
                   cfg.norm_eps)
    kr = apply_rope(
        jnp.einsum("bsd,de->bse", x, p["w_kr"])[:, :, None, :], positions,
        cfg.rope_theta)[:, :, 0]
    S_buf = ckv_cache.shape[1]
    bidx = jnp.arange(B)[:, None]
    idx = positions % S_buf
    ckv_cache = ckv_cache.at[bidx, idx].set(ckv.astype(ckv_cache.dtype))
    kr_cache = kr_cache.at[bidx, idx].set(kr.astype(kr_cache.dtype))
    kv_pos = kv_pos.at[bidx, idx].set(positions)
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, p["w_uk"]) * scale
    s = jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32),
                   ckv_cache.astype(jnp.float32))
    s += jnp.einsum("bshd,btd->bhst", (q_rope * scale).astype(jnp.float32),
                    kr_cache.astype(jnp.float32))
    valid = (kv_pos >= 0)[:, None, None, :] & \
        (kv_pos[:, None, None, :] <= positions[:, None, :, None])
    s = jnp.where(valid, s, A.NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    w = jnp.where(valid.any(-1)[..., None], w, 0.0)
    ctx = jnp.einsum("bhst,btr->bshr", w.astype(ckv_cache.dtype), ckv_cache)
    o = jnp.einsum("bshr,rhe->bshe", ctx, p["w_uv"])
    out = jnp.einsum("bshe,hed->bsd", o, p["w_o"])
    return out, (ckv_cache, kr_cache, kv_pos)


def block_extend(p, x, kind: LayerKind, cfg: ModelConfig, cache_entry,
                 kv_pos, positions):
    """Chunked-prefill block step: like block_decode but for Sc tokens."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind in (LayerKind.DENSE, LayerKind.MOE):
        if "xattn" in p:
            kv, enc_kv = cache_entry
        else:
            kv, enc_kv = cache_entry, None
        if cfg.attention == AttentionKind.MLA:
            y, new3 = mla_extend(p["attn"], h, cfg, kv[0], kv[1], kv_pos,
                                 positions)
        else:
            y, new3 = attn_extend(p["attn"], h, cfg, kv[0], kv[1], kv_pos,
                                  positions)
        new_entry, kv_pos = (new3[0], new3[1]), new3[2]
        if enc_kv is not None:
            new_entry = (new_entry, enc_kv)
    else:
        ssm_state, conv_state = cache_entry
        y, (ssm_state, conv_state) = mamba_forward(
            h, p["mamba"], cfg.ssm, ssm_state.astype(jnp.float32), conv_state)
        new_entry = (ssm_state, conv_state)
    x = x + y
    if "xattn" in p:
        h = rms_norm(x, p["ln_x"], cfg.norm_eps)
        _, enc_kv = cache_entry
        y, _ = cross_attn_full(p["xattn"], h, None, cfg, enc_kv=enc_kv)
        x = x + y
    if "moe" in p:
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        y, _ = moe_block(h, p["moe"], cfg.moe)
        x = x + y
    elif "mlp" in p:
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                       p["mlp"]["w_down"])
    return x, new_entry, kv_pos


# ---------------------------------------------------------------------------
# Paged chunk-extend (page-native prefill: chunks write straight into the
# BlockPool pages a finished request will hand to decode, so there is no
# dense staging cache and no handoff-realization scatter)
# ---------------------------------------------------------------------------

def _paged_write_sites(block_tab, positions, block_size):
    """Per-token physical (block, offset) write sites for a chunk.
    block_tab (B, nbt); positions (B, Sc).  Positions past the table (or
    rows with unset logical blocks) write into the reserved null block."""
    nbt = block_tab.shape[1]
    lb = jnp.clip(positions // block_size, 0, nbt - 1)       # (B, Sc)
    phys = jnp.take_along_axis(block_tab, lb, axis=1)        # (B, Sc)
    return jnp.maximum(phys, 0), positions % block_size


def attn_extend_paged(p, x, cfg: ModelConfig, k_pool, v_pool, kv_pos_pool,
                      block_tab, positions):
    """Chunk extend against a paged pool: scatter the chunk's K/V into the
    row's physical blocks, then attend q over the block-table gather of
    the whole pool view — earlier-chunk (and shared-prefix) KV is read
    THROUGH the table, exactly like `attn_decode_paged`, with
    `attn_extend`'s position masking for intra-chunk causality."""
    window = cfg.sliding_window if cfg.attention == AttentionKind.SWA else 0
    q = jnp.einsum("bsd,dhe->bshe", x, p["w_q"])
    k = jnp.einsum("bsd,dhe->bshe", x, p["w_k"])
    v = jnp.einsum("bsd,dhe->bshe", x, p["w_v"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    phys, off = _paged_write_sites(block_tab, positions, k_pool.shape[1])
    k_pool = k_pool.at[phys, off].set(k.astype(k_pool.dtype))
    v_pool = v_pool.at[phys, off].set(v.astype(v_pool.dtype))
    kv_pos_pool = kv_pos_pool.at[phys, off].set(positions)
    kg = A.gather_paged(k_pool, block_tab)                   # (B, nbt*bs, ...)
    vg = A.gather_paged(v_pool, block_tab)
    kv_pos_g = A.gather_paged_pos(kv_pos_pool, block_tab)
    S_view = kg.shape[1]
    o = A.flash_attention_xla(
        q, kg, vg, positions, kv_pos_g,
        causal=True, window=window,
        block=min(FLASH_BLOCK, S_view)) if S_view > FLASH_THRESHOLD else None
    if o is None:
        mask = A.build_mask(positions, kv_pos_g, causal=True, window=window)
        mask &= (kv_pos_g >= 0)[:, None, :]
        o = A.gqa_reference(q, kg, vg, mask)
    out = jnp.einsum("bshe,hed->bsd", o, p["w_o"])
    return out, (k_pool, v_pool, kv_pos_pool)


def mla_extend_paged(p, x, cfg: ModelConfig, ckv_pool, kr_pool, kv_pos_pool,
                     block_tab, positions):
    """Chunk extend for MLA (absorbed form) over paged latent pools."""
    m = cfg.mla
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    q_nope, q_rope = _mla_q(p, x, cfg, positions)            # (B,Sc,H,·)
    ckv = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dkv"]), p["kv_norm"],
                   cfg.norm_eps)
    kr = apply_rope(
        jnp.einsum("bsd,de->bse", x, p["w_kr"])[:, :, None, :], positions,
        cfg.rope_theta)[:, :, 0]
    phys, off = _paged_write_sites(block_tab, positions, ckv_pool.shape[1])
    ckv_pool = ckv_pool.at[phys, off].set(ckv.astype(ckv_pool.dtype))
    kr_pool = kr_pool.at[phys, off].set(kr.astype(kr_pool.dtype))
    kv_pos_pool = kv_pos_pool.at[phys, off].set(positions)
    ckv_g = A.gather_paged(ckv_pool, block_tab)
    kr_g = A.gather_paged(kr_pool, block_tab)
    kv_pos_g = A.gather_paged_pos(kv_pos_pool, block_tab)
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, p["w_uk"]) * scale
    s = jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32),
                   ckv_g.astype(jnp.float32))
    s += jnp.einsum("bshd,btd->bhst", (q_rope * scale).astype(jnp.float32),
                    kr_g.astype(jnp.float32))
    valid = (kv_pos_g >= 0)[:, None, None, :] & \
        (kv_pos_g[:, None, None, :] <= positions[:, None, :, None])
    s = jnp.where(valid, s, A.NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    w = jnp.where(valid.any(-1)[..., None], w, 0.0)
    ctx = jnp.einsum("bhst,btr->bshr", w.astype(ckv_g.dtype), ckv_g)
    o = jnp.einsum("bshr,rhe->bshe", ctx, p["w_uv"])
    out = jnp.einsum("bshe,hed->bsd", o, p["w_o"])
    return out, (ckv_pool, kr_pool, kv_pos_pool)


def block_extend_paged(p, x, kind: LayerKind, cfg: ModelConfig, cache_entry,
                       kv_pos_pool, block_tab, positions):
    """Chunked-prefill block step writing into paged pools.  Page-native
    prefill is attention-only (per-slot SSM / encoder state has no page
    representation — those configs keep the dense staging path)."""
    if kind not in (LayerKind.DENSE, LayerKind.MOE) or "xattn" in p:
        raise ValueError("paged prefill supports attention-only layers")
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    kv = cache_entry
    if cfg.attention == AttentionKind.MLA:
        y, new3 = mla_extend_paged(p["attn"], h, cfg, kv[0], kv[1],
                                   kv_pos_pool, block_tab, positions)
    else:
        y, new3 = attn_extend_paged(p["attn"], h, cfg, kv[0], kv[1],
                                    kv_pos_pool, block_tab, positions)
    new_entry, kv_pos_pool = (new3[0], new3[1]), new3[2]
    x = x + y
    if "moe" in p:
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        y, _ = moe_block(h, p["moe"], cfg.moe)
        x = x + y
    elif "mlp" in p:
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                       p["mlp"]["w_down"])
    return x, new_entry, kv_pos_pool


# ---------------------------------------------------------------------------
# Block-level apply
# ---------------------------------------------------------------------------

def block_full(p, x, kind: LayerKind, cfg: ModelConfig, positions, seg=None,
               causal=True, use_rope=True, enc_out=None,
               ssm_init=None, conv_init=None):
    """Full-sequence block. Returns (x, cache_entry, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind in (LayerKind.DENSE, LayerKind.MOE):
        if cfg.attention == AttentionKind.MLA:
            y, kv = mla_full(p["attn"], h, cfg, positions, seg)
        else:
            y, kv = attn_full(p["attn"], h, cfg, positions, seg, causal,
                              use_rope)
        cache_entry = kv
    else:
        y, state = mamba_forward(h, p["mamba"], cfg.ssm, ssm_init, conv_init)
        cache_entry = state
    x = x + y
    if "xattn" in p:
        h = rms_norm(x, p["ln_x"], cfg.norm_eps)
        y, enc_kv = cross_attn_full(p["xattn"], h, enc_out, cfg)
        x = x + y
        cache_entry = (cache_entry, enc_kv)
    if "moe" in p:
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        y, laux = moe_block(h, p["moe"], cfg.moe)
        x = x + y
        aux = aux + laux
    elif "mlp" in p:
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                       p["mlp"]["w_down"])
    return x, cache_entry, aux


def block_decode(p, x, kind: LayerKind, cfg: ModelConfig, cache_entry,
                 kv_pos, pos):
    """Single-token decode block. Returns (x, new_cache_entry, new_kv_pos).

    kv_pos is the shared per-model position map for attention caches
    (None for pure-SSM blocks).
    """
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind in (LayerKind.DENSE, LayerKind.MOE):
        if "xattn" in p:
            kv, enc_kv = cache_entry
        else:
            kv, enc_kv = cache_entry, None
        if cfg.attention == AttentionKind.MLA:
            y, new_kv3 = mla_decode(p["attn"], h, cfg, kv[0], kv[1], kv_pos, pos)
            new_entry, kv_pos = (new_kv3[0], new_kv3[1]), new_kv3[2]
        else:
            y, new_kv3 = attn_decode(p["attn"], h, cfg, kv[0], kv[1], kv_pos, pos)
            new_entry, kv_pos = (new_kv3[0], new_kv3[1]), new_kv3[2]
        if enc_kv is not None:
            new_entry = (new_entry, enc_kv)
    else:
        ssm_state, conv_state = cache_entry
        y, (ssm_state, conv_state) = mamba_decode_step(
            h, p["mamba"], cfg.ssm, ssm_state, conv_state)
        new_entry = (ssm_state, conv_state)
    x = x + y
    if "xattn" in p:
        h = rms_norm(x, p["ln_x"], cfg.norm_eps)
        _, enc_kv = cache_entry
        y, _ = cross_attn_full(p["xattn"], h, None, cfg, enc_kv=enc_kv)
        x = x + y
    if "moe" in p:
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        y, _ = moe_block(h, p["moe"], cfg.moe)
        x = x + y
    elif "mlp" in p:
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                       p["mlp"]["w_down"])
    return x, new_entry, kv_pos


def block_decode_paged(p, x, kind: LayerKind, cfg: ModelConfig, cache_entry,
                       kv_pos_pool, block_tab, pos):
    """Single-token decode block over a paged cache.  Attention entries
    are physical block pools (no batch axis — the batch lives in
    `block_tab`); SSM states and encoder K/V stay per-slot exactly as in
    `block_decode` (their footprint is O(1) per request, paging them
    would buy nothing).  Returns (x, new_cache_entry, new_kv_pos_pool)."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind in (LayerKind.DENSE, LayerKind.MOE):
        if "xattn" in p:
            kv, enc_kv = cache_entry
        else:
            kv, enc_kv = cache_entry, None
        if cfg.attention == AttentionKind.MLA:
            y, new3 = mla_decode_paged(p["attn"], h, cfg, kv[0], kv[1],
                                       kv_pos_pool, block_tab, pos)
        else:
            y, new3 = attn_decode_paged(p["attn"], h, cfg, kv[0], kv[1],
                                        kv_pos_pool, block_tab, pos)
        new_entry, kv_pos_pool = (new3[0], new3[1]), new3[2]
        if enc_kv is not None:
            new_entry = (new_entry, enc_kv)
    else:
        ssm_state, conv_state = cache_entry
        y, (ssm_state, conv_state) = mamba_decode_step(
            h, p["mamba"], cfg.ssm, ssm_state, conv_state)
        new_entry = (ssm_state, conv_state)
    x = x + y
    if "xattn" in p:
        h = rms_norm(x, p["ln_x"], cfg.norm_eps)
        _, enc_kv = cache_entry
        y, _ = cross_attn_full(p["xattn"], h, None, cfg, enc_kv=enc_kv)
        x = x + y
    if "moe" in p:
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        y, _ = moe_block(h, p["moe"], cfg.moe)
        x = x + y
    elif "mlp" in p:
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                       p["mlp"]["w_down"])
    return x, new_entry, kv_pos_pool
