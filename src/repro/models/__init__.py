from repro.models.model import (
    init_params,
    abstract_params,
    init_cache,
    abstract_cache,
    forward_train,
    loss_fn,
    prefill,
    decode_step,
)

__all__ = [
    "init_params",
    "abstract_params",
    "init_cache",
    "abstract_cache",
    "forward_train",
    "loss_fn",
    "prefill",
    "decode_step",
]
