from repro.models.model import (
    init_params,
    abstract_params,
    init_cache,
    abstract_cache,
    cache_join,
    cache_take,
    forward_train,
    loss_fn,
    prefill,
    prefill_chunk,
    decode_step,
)

__all__ = [
    "init_params",
    "abstract_params",
    "init_cache",
    "abstract_cache",
    "cache_join",
    "cache_take",
    "forward_train",
    "loss_fn",
    "prefill",
    "prefill_chunk",
    "decode_step",
]
