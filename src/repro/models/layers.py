"""Primitive layers: norms, RoPE, initializers, embeddings."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(dt)


def init_linear(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    s = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * s).astype(dtype)


def init_stacked(key, n: int, d_in: int, d_out: int, dtype,
                 scale: Optional[float] = None):
    s = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (n, d_in, d_out), jnp.float32) * s).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies, shape (head_dim // 2,)."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotate pairs. x: (..., S, n, head_dim); positions: (..., S) int32.

    Position axis is -3 (token axis); head axis -2.
    """
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)              # (half,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoid_pos_embedding(seq_len: int, dim: int) -> jnp.ndarray:
    """Whisper-style sinusoidal absolute position embedding (S, D)."""
    half = dim // 2
    log_timescale = math.log(10000.0) / max(half - 1, 1)
    inv = jnp.exp(-log_timescale * jnp.arange(half, dtype=jnp.float32))
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(pos), jnp.cos(pos)], axis=-1)


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
           w_down: jnp.ndarray) -> jnp.ndarray:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, w_down)
