"""Mamba2 (SSD — state-space duality) block, chunked scan + O(1) decode.

Follows the minimal SSD formulation of arXiv:2405.21060 §6: the sequence is
split into chunks of Q tokens; within a chunk the output is a masked
attention-like quadratic term (MXU-friendly), across chunks a linear
recurrence carries the (heads, head_dim, d_state) state. Decode is a single
state update per token — this is why SSM archs run the long_500k shape.

The Pallas kernel in repro.kernels.ssd_scan implements the intra-chunk term
with explicit VMEM tiling; this module is the pure-jnp path/oracle.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import SSMConfig
from repro.models.layers import init_linear, rms_norm


def ssm_dims(d_model: int, sc: SSMConfig):
    d_inner = d_model * sc.expand
    n_heads = d_inner // sc.head_dim
    conv_dim = d_inner + 2 * sc.n_groups * sc.d_state
    return d_inner, n_heads, conv_dim


def init_mamba_params(key, d_model: int, sc: SSMConfig, dtype) -> Dict:
    """Projections are SPLIT ([z|x] / [B|C] / dt) so every matrix shards
    cleanly on its own output dim — a fused in_proj's split boundaries do
    not align with model-axis shards and GSPMD replicates the whole SSD
    block (§Perf iteration 3: jamba train was 16× over-computing)."""
    di, nh, cdim = ssm_dims(d_model, sc)
    gds2 = 2 * sc.n_groups * sc.d_state
    ks = jax.random.split(key, 6)
    return {
        "w_zx": init_linear(ks[0], d_model, 2 * di, dtype),
        "w_bc": init_linear(ks[1], d_model, gds2, dtype),
        "w_dt": init_linear(ks[2], d_model, nh, dtype),
        "conv_wx": (jax.random.normal(ks[3], (sc.d_conv, di), jnp.float32)
                    / math.sqrt(sc.d_conv)).astype(dtype),
        "conv_bx": jnp.zeros((di,), dtype),
        "conv_wbc": (jax.random.normal(ks[4], (sc.d_conv, gds2), jnp.float32)
                     / math.sqrt(sc.d_conv)).astype(dtype),
        "conv_bbc": jnp.zeros((gds2,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        "D_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": jnp.ones((di,), dtype),
        "out_proj": init_linear(ks[5], di, d_model, dtype),
    }


def _project(x, params, di, nh):
    zx = jnp.einsum("bsd,de->bse", x, params["w_zx"])
    z, xs = zx[..., :di], zx[..., di:]
    bc = jnp.einsum("bsd,de->bse", x, params["w_bc"])
    dt = jnp.einsum("bsd,de->bse", x, params["w_dt"])
    return z, xs, bc, dt


def _causal_conv(xBC, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv1d over the token axis. xBC: (B, S, C).
    conv_state: (B, d_conv-1, C) previous-token tail or None (zeros)."""
    dconv = conv_w.shape[0]
    B = xBC.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((B, dconv - 1, xBC.shape[-1]), xBC.dtype)
    full = jnp.concatenate([conv_state, xBC], axis=1)
    out = jnp.zeros_like(xBC, dtype=jnp.float32)
    S = xBC.shape[1]
    for w in range(dconv):
        out = out + full[:, w:w + S].astype(jnp.float32) * conv_w[w].astype(jnp.float32)
    out = jax.nn.silu(out + conv_b.astype(jnp.float32)).astype(xBC.dtype)
    new_state = full[:, full.shape[1] - (dconv - 1):]
    return out, new_state


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, initial_state=None):
    """SSD chunked scan.

    x: (B, S, nh, hp); dt: (B, S, nh) (already softplus'd, f32);
    A: (nh,) negative; Bm, Cm: (B, S, g, ds).
    Returns y (B, S, nh, hp) and final state (B, nh, hp, ds).
    """
    Bsz, S, nh, hp = x.shape
    g, ds = Bm.shape[2], Bm.shape[3]
    hpg = nh // g                      # heads per group
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk

    def to_chunks(a):
        return jnp.moveaxis(a.reshape(Bsz, nc, chunk, *a.shape[2:]), 1, 0)

    xs = (to_chunks(x), to_chunks(dt), to_chunks(Bm), to_chunks(Cm))
    h0 = (initial_state if initial_state is not None
          else jnp.zeros((Bsz, nh, hp, ds), jnp.float32))

    def body2(h, xs_c):
        xc, dtc, Bc, Cc = xs_c
        xc32 = xc.astype(jnp.float32)
        Bc32 = Bc.astype(jnp.float32)
        Cc32 = Cc.astype(jnp.float32)
        dA = dtc * A                               # (B,Q,nh)
        dA_cum = jnp.cumsum(dA, axis=1)
        # heads grouped: head index h = (g, i) with i in [0, hpg)
        hg = h.reshape(Bsz, g, hpg, hp, ds)        # carry-in state
        # off-diagonal: y_off[b,q,g,i,p] = decay_in * Σ_n C[b,q,g,n]·h[b,g,i,p,n]
        y_off = jnp.einsum("bqgn,bgipn->bqgip", Cc32, hg)
        y_off = y_off * jnp.exp(dA_cum).reshape(Bsz, chunk, g, hpg)[..., None]
        # intra-chunk: L[b,q,k,h] = exp(dA_cum[q]-dA_cum[k]) for q>=k.
        # mask BEFORE exp: masked rel is positive and can overflow, and
        # inf·0 in the backward poisons grads with NaNs.
        rel = dA_cum[:, :, None, :] - dA_cum[:, None, :, :]   # (B,Q,Q,nh)
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        L = jnp.exp(jnp.where(causal[None, :, :, None], rel, -1e30))
        CB = jnp.einsum("bqgn,bkgn->bqkg", Cc32, Bc32)        # (B,Q,Q,g)
        Lg = L.reshape(Bsz, chunk, chunk, g, hpg)
        att = CB[..., None] * Lg * dtc.reshape(Bsz, 1, chunk, g, hpg)
        xg = xc32.reshape(Bsz, chunk, g, hpg, hp)
        y_diag = jnp.einsum("bqkgi,bkgip->bqgip", att, xg)
        # chunk state contribution: S[b,g,i,p,n] = Σ_k decay_out·dt·B·x
        decay_out = jnp.exp(dA_cum[:, -1:, :] - dA_cum)        # (B,Q,nh)
        w = (decay_out * dtc).reshape(Bsz, chunk, g, hpg)
        states = jnp.einsum("bkgi,bkgn,bkgip->bgipn", w, Bc32, xg)
        chunk_decay = jnp.exp(dA_cum[:, -1, :]).reshape(Bsz, g, hpg)
        h_new = hg * chunk_decay[..., None, None] + states
        y = (y_diag + y_off).reshape(Bsz, chunk, nh, hp)
        return h_new.reshape(Bsz, nh, hp, ds), y

    h_final, ys = jax.lax.scan(body2, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, Sp, nh, hp)[:, :S]
    return y, h_final


def mamba_forward(x, params, sc: SSMConfig, initial_state=None, conv_state=None):
    """Full-sequence forward. x: (B, S, D).

    Returns (out (B,S,D), (ssm_state, conv_state)) for chunked continuation.
    """
    d_model = x.shape[-1]
    di, nh, cdim = ssm_dims(d_model, sc)
    gds = sc.n_groups * sc.d_state
    z, xr, bc, dt = _project(x, params, di, nh)
    cs_x, cs_bc = (conv_state if conv_state is not None else (None, None))
    xr, ncs_x = _causal_conv(xr, params["conv_wx"], params["conv_bx"], cs_x)
    bc, ncs_bc = _causal_conv(bc, params["conv_wbc"], params["conv_bbc"],
                              cs_bc)
    new_conv_state = (ncs_x, ncs_bc)
    xs = xr.reshape(*xr.shape[:2], nh, sc.head_dim)
    Bm = bc[..., :gds].reshape(*bc.shape[:2], sc.n_groups, sc.d_state)
    Cm = bc[..., gds:].reshape(*bc.shape[:2], sc.n_groups, sc.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    y, h = ssd_chunked(xs, dt, A, Bm, Cm, sc.chunk_size, initial_state)
    y = y + xs.astype(jnp.float32) * params["D_skip"][:, None]
    y = y.reshape(*y.shape[:2], di)
    y = y.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rms_norm(y, params["norm"])
    out = jnp.einsum("bsd,de->bse", y, params["out_proj"])
    return out, (h, new_conv_state)


def mamba_decode_step(x, params, sc: SSMConfig, ssm_state, conv_state):
    """Single-token decode. x: (B, 1, D); ssm_state: (B, nh, hp, ds) f32;
    conv_state: (B, d_conv-1, conv_dim). O(1) in context length."""
    d_model = x.shape[-1]
    di, nh, cdim = ssm_dims(d_model, sc)
    gds = sc.n_groups * sc.d_state
    g, ds, hp = sc.n_groups, sc.d_state, sc.head_dim
    hpg = nh // g
    z, xr, bc, dt = _project(x, params, di, nh)
    cs_x, cs_bc = conv_state
    xr, ncs_x = _causal_conv(xr, params["conv_wx"], params["conv_bx"], cs_x)
    bc, ncs_bc = _causal_conv(bc, params["conv_wbc"], params["conv_bbc"],
                              cs_bc)
    new_conv_state = (ncs_x, ncs_bc)
    xt = xr[:, 0].reshape(-1, nh, hp).astype(jnp.float32)
    Bt = bc[:, 0, :gds].reshape(-1, g, ds).astype(jnp.float32)
    Ct = bc[:, 0, gds:].reshape(-1, g, ds).astype(jnp.float32)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,nh)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt * A)                                   # (B,nh)
    xg = xt.reshape(-1, g, hpg, hp)
    dtg = dt.reshape(-1, g, hpg)
    upd = jnp.einsum("bgi,bgn,bgip->bgipn", dtg, Bt, xg)
    hg = ssm_state.reshape(-1, g, hpg, hp, ds)
    hg = hg * dA.reshape(-1, g, hpg)[..., None, None] + upd
    y = jnp.einsum("bgn,bgipn->bgip", Ct, hg).reshape(-1, nh, hp)
    y = y + xt * params["D_skip"][:, None]
    y = y.reshape(-1, 1, di)
    y = y.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rms_norm(y, params["norm"])
    out = jnp.einsum("bsd,de->bse", y, params["out_proj"])
    return out, (hg.reshape(-1, nh, hp, ds), new_conv_state)


def ssd_chunked_kernel(x, dt, A, Bm, Cm, chunk: int, initial_state=None,
                       interpret=None):
    """ssd_chunked with the intra-chunk work done by the Pallas kernel
    (repro.kernels.ssd_scan); only the tiny inter-chunk recurrence stays in
    a jax.lax.scan. Numerically equivalent to ssd_chunked (tested)."""
    from repro.kernels.ssd_scan.ops import ssd_chunk_kernel_apply
    Bsz, S, nh, hp = x.shape
    g, ds = Bm.shape[2], Bm.shape[3]
    assert g == 1, "kernel path supports n_groups=1"
    hpg = nh // g
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk
    xc = x.reshape(Bsz, nc, chunk, nh, hp)
    dtc = dt.reshape(Bsz, nc, chunk, nh)
    Bc = Bm.reshape(Bsz, nc, chunk, ds)
    Cc = Cm.reshape(Bsz, nc, chunk, ds)
    y_diag, states = ssd_chunk_kernel_apply(xc, dtc, A, Bc, Cc,
                                            interpret=interpret)
    # inter-chunk recurrence + carry-in output term (XLA)
    dA_cum = jnp.cumsum(dtc * A, axis=2)               # (B,nc,Q,nh)
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])         # (B,nc,nh)
    h0 = (initial_state if initial_state is not None
          else jnp.zeros((Bsz, nh, hp, ds), jnp.float32))

    def body(h, xs_c):
        Cm_c, decay_c, dAc_c, st_c = xs_c
        y_off = jnp.einsum("bqn,bhpn->bqhp", Cm_c.astype(jnp.float32), h)
        y_off = y_off * jnp.exp(dAc_c)[..., None].transpose(0, 1, 2, 3)
        h_new = h * decay_c[:, :, None, None] + st_c.transpose(0, 1, 3, 2)
        return h_new, y_off

    xs = (jnp.moveaxis(Cc, 1, 0), jnp.moveaxis(chunk_decay, 1, 0),
          jnp.moveaxis(dA_cum, 1, 0), jnp.moveaxis(states, 1, 0))
    h_final, y_offs = jax.lax.scan(body, h0, xs)
    y_off = jnp.moveaxis(y_offs, 0, 1).reshape(Bsz, Sp, nh, hp)
    y = (y_diag.reshape(Bsz, Sp, nh, hp) + y_off)[:, :S]
    return y, h_final


def ssd_reference(x, dt, A, Bm, Cm, initial_state=None):
    """O(S²) or sequential-scan oracle for ssd_chunked (tests only).

    Direct recurrence: h_t = h_{t-1}·exp(dt_t A) + dt_t · B_t ⊗ x_t;
    y_t = C_t · h_t.
    """
    Bsz, S, nh, hp = x.shape
    g, ds = Bm.shape[2], Bm.shape[3]
    hpg = nh // g
    h = (initial_state if initial_state is not None
         else jnp.zeros((Bsz, nh, hp, ds), jnp.float32)).reshape(Bsz, g, hpg, hp, ds)

    def step(h, t):
        xt = x[:, t].astype(jnp.float32).reshape(Bsz, g, hpg, hp)
        Bt = Bm[:, t].astype(jnp.float32)
        Ct = Cm[:, t].astype(jnp.float32)
        dtt = dt[:, t].reshape(Bsz, g, hpg)
        dA = jnp.exp(dtt * A.reshape(g, hpg))
        upd = jnp.einsum("bgi,bgn,bgip->bgipn", dtt, Bt, xt)
        h = h * dA[..., None, None] + upd
        y = jnp.einsum("bgn,bgipn->bgip", Ct, h)
        return h, y.reshape(Bsz, nh, hp)

    h, ys = jax.lax.scan(step, h, jnp.arange(S))
    return jnp.moveaxis(ys, 0, 1), h.reshape(Bsz, nh, hp, ds)
