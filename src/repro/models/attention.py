"""Attention math: GQA einsum reference, flash-style XLA attention (online
softmax over KV blocks — the jnp mirror of the Pallas kernel), and decode
attention over a KV cache.

Shapes: q (B, Sq, H, hd); k,v (B, Skv, K, hd) with K = num_kv_heads,
G = H // K query groups. Positions/segments are per-token int32 arrays;
segment id -1 marks padding. Packed varlen chunked-prefill (the paper's
C_chunk unit) is expressed through segment ids.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def build_mask(
    q_pos: jnp.ndarray,            # (B, Sq)
    kv_pos: jnp.ndarray,           # (B, Skv)
    q_seg: Optional[jnp.ndarray] = None,
    kv_seg: Optional[jnp.ndarray] = None,
    causal: bool = True,
    window: int = 0,
) -> jnp.ndarray:
    """Boolean (B, Sq, Skv) mask; True = attend."""
    m = kv_pos[:, None, :] >= 0   # negative position = empty cache slot
    m = jnp.broadcast_to(m, (q_pos.shape[0], q_pos.shape[1], kv_pos.shape[1]))
    if causal:
        m = m & (q_pos[:, :, None] >= kv_pos[:, None, :])
    if window > 0:
        m &= (q_pos[:, :, None] - kv_pos[:, None, :]) < window
    if q_seg is not None and kv_seg is not None:
        m &= q_seg[:, :, None] == kv_seg[:, None, :]
        m &= kv_seg[:, None, :] >= 0
    return m


def gqa_reference(q, k, v, mask):
    """Naive einsum GQA attention (oracle for flash paths and kernels)."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    scale = hd ** -0.5
    qg = q.reshape(B, Sq, K, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32) * scale
    s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows produce uniform weights; zero them out
    any_valid = mask.any(axis=-1)[:, None, None, :, None]
    p = jnp.where(any_valid, p, 0.0).astype(v.dtype)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v)
    return o.reshape(B, Sq, H, v.shape[-1])


def flash_attention_xla(
    q, k, v,
    q_pos, kv_pos,
    q_seg=None, kv_seg=None,
    causal: bool = True,
    window: int = 0,
    block: int = 512,
    sorted_layout: bool = False,
):
    """Online-softmax attention scanning KV blocks: O(Sq·block) live memory.

    Matches gqa_reference numerically (same masking semantics). This is what
    XLA compiles for long-context prefill; the Pallas kernel in
    repro.kernels.flash_prefill implements the same schedule with explicit
    VMEM tiling for TPU.

    sorted_layout=True asserts tokens are laid out in temporal order (true
    for full prefill and packed varlen chunks, NOT for ring caches): with
    causal masking the strictly-upper-triangular kv blocks are then skipped
    entirely (§Perf iteration 4 — ~2× attention FLOPs on long prefill).
    """
    if sorted_layout and causal and q.shape[1] == k.shape[1]:
        return _blockskip_vjp(window, block)(q, k, v, q_pos, kv_pos,
                                             q_seg, kv_seg)
    return _flash_scan(q, k, v, q_pos, kv_pos, q_seg, kv_seg, causal,
                       window, block)


@functools.lru_cache(maxsize=None)
def _blockskip_vjp(window: int, block: int):
    """Block-skip forward is a dynamic-bound fori_loop (not reverse-mode
    differentiable); custom_vjp routes the backward through the full scan
    path's VJP (identical math, no skipping in bwd). Positions/segments are
    integer args with float0 cotangents."""
    import numpy as np

    @jax.custom_vjp
    def f(q, k, v, q_pos, kv_pos, q_seg, kv_seg):
        return _flash_causal_blockskip(q, k, v, q_pos, kv_pos, q_seg,
                                       kv_seg, window, block)

    def f_fwd(q, k, v, q_pos, kv_pos, q_seg, kv_seg):
        out = f(q, k, v, q_pos, kv_pos, q_seg, kv_seg)
        return out, (q, k, v, q_pos, kv_pos, q_seg, kv_seg)

    def f_bwd(res, g):
        q, k, v, q_pos, kv_pos, q_seg, kv_seg = res
        _, vjp = jax.vjp(
            lambda q_, k_, v_: _flash_scan(q_, k_, v_, q_pos, kv_pos,
                                           q_seg, kv_seg, True, window,
                                           block), q, k, v)
        dq, dk, dv = vjp(g)

        def f0(x):
            return (np.zeros(x.shape, jax.dtypes.float0)
                    if x is not None else None)
        return (dq, dk, dv, f0(q_pos), f0(kv_pos), f0(q_seg), f0(kv_seg))

    f.defvjp(f_fwd, f_bwd)
    return f


def _flash_scan(q, k, v, q_pos, kv_pos, q_seg, kv_seg, causal, window,
                block):
    B, Sq, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    G = H // K
    scale = hd ** -0.5
    if Skv % block != 0:
        pad = block - Skv % block
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=2**30)
        if kv_seg is not None:
            kv_seg = jnp.pad(kv_seg, ((0, 0), (0, pad)), constant_values=-2)
        Skv = k.shape[1]
    nb = Skv // block

    qg = q.reshape(B, Sq, K, G, hd)
    ks = jnp.moveaxis(k.reshape(B, nb, block, K, hd), 1, 0)
    vs = jnp.moveaxis(v.reshape(B, nb, block, K, v.shape[-1]), 1, 0)
    kps = jnp.moveaxis(kv_pos.reshape(B, nb, block), 1, 0)
    kss = (jnp.moveaxis(kv_seg.reshape(B, nb, block), 1, 0)
           if kv_seg is not None else None)

    hd_v = v.shape[-1]
    m0 = jnp.full((B, K, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, K, G, Sq, hd_v), jnp.float32)

    def body(carry, xs):
        m, l, acc = carry
        if kss is not None:
            kb, vb, kpb, ksb = xs
        else:
            kb, vb, kpb = xs
            ksb = None
        s = jnp.einsum("bqkgh,bskh->bkgqs", qg, kb).astype(jnp.float32) * scale
        mask = build_mask(q_pos, kpb, q_seg, ksb, causal, window)
        s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[:, None, None, :, :], p, 0.0)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskh->bkgqh", p.astype(vb.dtype), vb
        ).astype(jnp.float32)
        return (m_new, l, acc), None

    xs = (ks, vs, kps) if kss is None else (ks, vs, kps, kss)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), xs)
    out = jnp.where(l[..., None] > 0, acc / jnp.maximum(l, 1e-30)[..., None], 0.0)
    out = jnp.moveaxis(out, (1, 2), (2, 3))  # (B,K,G,Sq,hd)->(B,Sq,K,G,hd)
    return out.reshape(B, Sq, H, hd_v).astype(q.dtype)


def _flash_causal_blockskip(q, k, v, q_pos, kv_pos, q_seg, kv_seg,
                            window: int, block: int):
    """Block-skipping flash attention for temporally-ordered layouts:
    q block i only visits kv blocks 0..i (fori_loop with a dynamic bound) —
    the strictly-upper triangle is never computed."""
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    hd_v = v.shape[-1]
    scale = hd ** -0.5
    pad = (-S) % block
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)), constant_values=-(2**30))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=2**30)
        if q_seg is not None:
            q_seg = jnp.pad(q_seg, ((0, 0), (0, pad)), constant_values=-1)
            kv_seg = jnp.pad(kv_seg, ((0, 0), (0, pad)), constant_values=-2)
    Sp = S + pad
    nb = Sp // block
    qb = jnp.moveaxis(q.reshape(B, nb, block, K, G, hd), 1, 0)
    qpb = jnp.moveaxis(q_pos.reshape(B, nb, block), 1, 0)
    qsb = (jnp.moveaxis(q_seg.reshape(B, nb, block), 1, 0)
           if q_seg is not None else None)

    def q_block(carry, xs):
        i = xs[0]
        qi = xs[1]                                   # (B, block, K, G, hd)
        qpi = xs[2]
        qsi = xs[3] if qsb is not None else None
        m0 = jnp.full((B, K, G, block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, block), jnp.float32)
        a0 = jnp.zeros((B, K, G, block, hd_v), jnp.float32)

        def kv_step(j, st):
            m, l, acc = st
            kb = jax.lax.dynamic_slice_in_dim(k, j * block, block, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, j * block, block, axis=1)
            kpb = jax.lax.dynamic_slice_in_dim(kv_pos, j * block, block,
                                               axis=1)
            s = jnp.einsum("bqkgh,bskh->bkgqs", qi, kb
                           ).astype(jnp.float32) * scale
            mask = (kpb[:, None, :] <= qpi[:, :, None]) & \
                (kpb[:, None, :] >= 0) & (qpi[:, :, None] >= 0)
            if window > 0:
                mask &= (qpi[:, :, None] - kpb[:, None, :]) < window
            if qsb is not None:
                ksb = jax.lax.dynamic_slice_in_dim(kv_seg, j * block, block,
                                                   axis=1)
                mask &= (qsi[:, :, None] == ksb[:, None, :]) & \
                    (ksb[:, None, :] >= 0)
            s = jnp.where(mask[:, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.where(mask[:, None, None], jnp.exp(s - m_new[..., None]),
                          0.0)
            alpha = jnp.exp(m - m_new)
            l = l * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p.astype(vb.dtype), vb
            ).astype(jnp.float32)
            return m_new, l, acc

        m, l, acc = jax.lax.fori_loop(0, i + 1, kv_step, (m0, l0, a0))
        out = jnp.where(l[..., None] > 0,
                        acc / jnp.maximum(l, 1e-30)[..., None], 0.0)
        return carry, out                              # (B,K,G,block,hd_v)

    _, outs = jax.lax.scan(
        q_block, None,
        (jnp.arange(nb), qb, qpb) + ((qsb,) if qsb is not None else ()))
    # outs: (nb, B, K, G, block, hd_v) -> (B, nb·block, K, G, hd_v)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sp, K, G, hd_v)
    return out.reshape(B, Sp, H, hd_v)[:, :S].astype(q.dtype)


def decode_attention(q, k_cache, v_cache, kv_pos, pos, window: int = 0):
    """Single-token decode attention.

    q: (B, 1, H, hd); caches: (B, S, K, hd); kv_pos: (B, S) int32 (−1 = empty);
    pos: (B,) int32 current positions. Memory-bound by design: one pass over
    the cache (the repro.kernels.decode_attention Pallas kernel tiles this).
    """
    B, _, H, hd = q.shape
    K = k_cache.shape[2]
    G = H // K
    scale = hd ** -0.5
    qg = q.reshape(B, K, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache).astype(jnp.float32) * scale
    valid = (kv_pos >= 0) & (kv_pos <= pos[:, None])
    if window > 0:
        valid &= (pos[:, None] - kv_pos) < window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(valid.any(-1)[:, None, None, None], p, 0.0).astype(v_cache.dtype)
    o = jnp.einsum("bkgs,bskh->bkgh", p, v_cache)
    return o.reshape(B, 1, H, hd).astype(q.dtype)


def gather_paged(pool, block_tab):
    """Block-gather read: materialise a request-contiguous view of a paged
    pool.  pool (N, bs, ...); block_tab (B, nbt) int32 with -1 = unset
    (mapped onto physical block 0, the null block).  Returns
    (B, nbt*bs, ...) — the dense layout `decode_attention` expects."""
    B, nbt = block_tab.shape
    bs = pool.shape[1]
    g = pool[jnp.maximum(block_tab, 0)]            # (B, nbt, bs, ...)
    return g.reshape((B, nbt * bs) + pool.shape[2:])


def gather_paged_pos(kv_pos_pool, block_tab):
    """Positions of a block-gathered view.  Unset table entries read as -1
    (empty) regardless of what inactive rows scribbled into the null
    block — this is what keeps the null block safe to share."""
    B, nbt = block_tab.shape
    g = jnp.where(block_tab[..., None] < 0, -1,
                  kv_pos_pool[jnp.maximum(block_tab, 0)])
    return g.reshape(B, nbt * kv_pos_pool.shape[1])


def decode_attention_paged(q, k_pool, v_pool, kv_pos_pool, block_tab, pos,
                           window: int = 0):
    """Single-token decode attention over a paged (block-table) KV cache.

    q: (B, 1, H, hd); pools: (N, bs, K, hd); kv_pos_pool: (N, bs) int32;
    block_tab: (B, nbt) int32 (-1 = unset); pos: (B,) int32.

    This is the dense-gather REFERENCE path: it materialises each row's
    blocks into a contiguous (B, nbt*bs, ...) view and reuses
    `decode_attention` unchanged.  The Pallas kernel
    (repro.kernels.decode_attention.paged_decode_attention) streams the
    same blocks through VMEM via scalar-prefetched table lookups without
    the materialisation.
    """
    k = gather_paged(k_pool, block_tab)
    v = gather_paged(v_pool, block_tab)
    kv_pos = gather_paged_pos(kv_pos_pool, block_tab)
    return decode_attention(q, k, v, kv_pos, pos, window)


def mla_scores_decode(q_latent, q_rope, c_cache, kr_cache, kv_pos, pos):
    """Absorbed-form MLA decode: q_latent (B,H,r) scores against the latent
    cache directly (no per-head K materialization).

    c_cache: (B, S, r); kr_cache: (B, S, dr); q_rope: (B, H, dr).
    Returns weights (B, H, S) in f32 and the validity mask.
    """
    s = jnp.einsum("bhr,bsr->bhs", q_latent, c_cache).astype(jnp.float32)
    s += jnp.einsum("bhd,bsd->bhs", q_rope, kr_cache).astype(jnp.float32)
    valid = (kv_pos >= 0) & (kv_pos <= pos[:, None])
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(valid.any(-1)[:, None, None], p, 0.0)
    return p, valid
