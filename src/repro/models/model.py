"""Model assembly: scan-over-layers transformer covering all six assigned
architecture families (dense / MoE / SSM / hybrid / VLM / audio enc-dec).

Layer stacking: `dense_prefix` layers are scanned as one stack; the remaining
layers are grouped into `reps` repetitions of `cfg.layer_pattern`, scanned
over reps with the pattern slots applied sequentially inside the body. This
keeps the HLO O(1) in depth (DeepSeek-V3's 61 layers compile as 2 scans).

Public API (all pure, cfg static):
    init_params / abstract_params
    init_cache  / abstract_cache
    prefill(cfg, params, tokens, ...)  -> (logits_last, cache)
    decode_step(cfg, params, token, cache) -> (logits, cache)
    cache_join(dst, src, slot) / cache_take(src, slot)   (continuous batching)
    forward_train / loss_fn
"""
from __future__ import annotations

import functools
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import AttentionKind, LayerKind, ModelConfig
from repro.models.blocks import (
    block_decode, block_full, init_block_params,
)
from repro.models.layers import rms_norm, sinusoid_pos_embedding
from repro.models.mamba import ssm_dims


def _ckpt(fn, remat):
    """remat: False/"none" (no remat), True/"block" (full recompute),
    "dots" (save matmul outputs — recompute only the cheap elementwise
    chains; §Perf iteration 5)."""
    if not remat or remat == "none":
        return fn
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Structure helpers
# ---------------------------------------------------------------------------

def layer_layout(cfg: ModelConfig) -> Tuple[int, Tuple[LayerKind, ...], int]:
    """(prefix_count, pattern, reps). Validates divisibility."""
    P = cfg.dense_prefix
    pattern = cfg.layer_pattern
    rest = cfg.num_layers - P
    if rest % len(pattern) != 0:
        raise ValueError(
            f"{cfg.name}: {rest} non-prefix layers not divisible by "
            f"pattern of length {len(pattern)}")
    return P, pattern, rest // len(pattern)


def _has_attn_cache(cfg: ModelConfig) -> bool:
    return any(k in (LayerKind.DENSE, LayerKind.MOE) for k in cfg.layer_kinds())


def kv_buffer_len(cfg: ModelConfig, max_len: int) -> int:
    if cfg.attention == AttentionKind.SWA and cfg.sliding_window:
        return min(cfg.sliding_window, max_len)
    return max_len


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def _stacked_blocks(key, n: int, cfg: ModelConfig, kind: LayerKind, dtype,
                    cross: bool = False):
    keys = jax.random.split(key, n)
    return jax.vmap(
        lambda k: init_block_params(k, cfg, kind, dtype, cross=cross)
    )(keys)


def init_params(cfg: ModelConfig, key, dtype=jnp.float32) -> Dict:
    P, pattern, reps = layer_layout(cfg)
    ks = iter(jax.random.split(key, 16))
    D, V = cfg.d_model, cfg.vocab_size
    params: Dict = {
        "embed": (jax.random.normal(next(ks), (V, D), jnp.float32)
                  * 0.02).astype(dtype),
        "ln_f": jnp.ones((D,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(next(ks), (D, V), jnp.float32)
                             / math.sqrt(D)).astype(dtype)
    cross = cfg.is_encoder_decoder
    if P:
        params["prefix"] = _stacked_blocks(next(ks), P, cfg, LayerKind.DENSE,
                                           dtype, cross=cross)
    blocks = {}
    for j, kind in enumerate(pattern):
        blocks[f"p{j}"] = _stacked_blocks(next(ks), reps, cfg, kind, dtype,
                                          cross=cross)
    params["blocks"] = blocks
    if cfg.is_encoder_decoder:
        params["encoder"] = _stacked_blocks(
            next(ks), cfg.num_encoder_layers, cfg, LayerKind.DENSE, dtype)
        params["enc_ln_f"] = jnp.ones((D,), dtype)
    if cfg.mtp_depth > 0:
        params["mtp"] = {
            "ln_h": jnp.ones((D,), dtype),
            "ln_e": jnp.ones((D,), dtype),
            "proj": (jax.random.normal(next(ks), (2 * D, D), jnp.float32)
                     / math.sqrt(2 * D)).astype(dtype),
            "block": init_block_params(next(ks), cfg, pattern[0], dtype),
        }
    return params


def abstract_params(cfg: ModelConfig, dtype=jnp.bfloat16):
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(functools.partial(init_params, cfg, dtype=dtype), key)


# ---------------------------------------------------------------------------
# Cache init
# ---------------------------------------------------------------------------

def _entry_struct(cfg: ModelConfig, kind: LayerKind, B: int, S_buf: int,
                  dtype, enc_len: int = 0):
    hd = cfg.resolved_head_dim
    K = cfg.num_kv_heads
    if kind in (LayerKind.DENSE, LayerKind.MOE):
        if cfg.attention == AttentionKind.MLA:
            m = cfg.mla
            kv = (jnp.zeros((B, S_buf, m.kv_lora_rank), dtype),
                  jnp.zeros((B, S_buf, m.qk_rope_head_dim), dtype))
        else:
            kv = (jnp.zeros((B, S_buf, K, hd), dtype),
                  jnp.zeros((B, S_buf, K, hd), dtype))
        if cfg.is_encoder_decoder:
            enc_kv = (jnp.zeros((B, enc_len, K, hd), dtype),
                      jnp.zeros((B, enc_len, K, hd), dtype))
            return (kv, enc_kv)
        return kv
    di, nh, cdim = ssm_dims(cfg.d_model, cfg.ssm)
    gds2 = 2 * cfg.ssm.n_groups * cfg.ssm.d_state
    entry = (jnp.zeros((B, nh, cfg.ssm.head_dim, cfg.ssm.d_state), jnp.float32),
             (jnp.zeros((B, cfg.ssm.d_conv - 1, di), dtype),
              jnp.zeros((B, cfg.ssm.d_conv - 1, gds2), dtype)))
    if cfg.is_encoder_decoder:
        enc_kv = (jnp.zeros((B, enc_len, K, hd), dtype),
                  jnp.zeros((B, enc_len, K, hd), dtype))
        return (entry, enc_kv)
    return entry


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.float32) -> Dict:
    P, pattern, reps = layer_layout(cfg)
    S_buf = kv_buffer_len(cfg, max_len) if _has_attn_cache(cfg) else 1
    enc_len = cfg.encoder_seq_len if cfg.is_encoder_decoder else 0

    def stack(n, kind):
        e = _entry_struct(cfg, kind, batch, S_buf, dtype, enc_len)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy(), e)

    cache: Dict = {
        "cur": jnp.zeros((batch,), jnp.int32),
        "kv_pos": jnp.full((batch, S_buf), -1, jnp.int32),
    }
    if P:
        cache["prefix"] = stack(P, LayerKind.DENSE)
    cache["blocks"] = {f"p{j}": stack(reps, kind)
                       for j, kind in enumerate(pattern)}
    return cache


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16):
    return jax.eval_shape(
        functools.partial(init_cache, cfg, batch, max_len, dtype))


# ---------------------------------------------------------------------------
# Continuous-batching cache surgery (join-on-handoff / leave-on-finish)
# ---------------------------------------------------------------------------
#
# A batched decode cache is a padded ring of `max_batch` independent slots:
# rows never interact through attention (each row attends only to its own
# KV) so a slot can be overwritten ("join") or abandoned ("leave") without
# touching its neighbours.  The batch axis is 0 for the top-level
# `cur`/`kv_pos` arrays and 1 for every stacked per-layer entry (axis 0 is
# the layer stack).  Inactive slots keep stepping on garbage — harmless,
# because attn_decode writes the current token's K/V before attending, so
# a fresh slot always has >= 1 valid key (no empty-softmax NaNs).  The one
# cross-row coupling is MoE expert *capacity*, which is computed over the
# whole batch: padded slots can contend for expert slots, so batched MoE
# decode is equivalent to serial decode only up to capacity pressure
# (dense/SSM architectures are exactly equivalent).


def _slot_axis(key: str) -> int:
    return 0 if key in ("cur", "kv_pos") else 1


def cache_join(dst: Dict, src: Dict, slot) -> Dict:
    """Insert the batch-1 cache `src` (a finished prefill) into slot `slot`
    of the padded batch cache `dst`.  Both caches must share the same
    model config and max_len.  `slot` may be a traced int32 (jit-safe)."""
    if dst["kv_pos"].shape[1] != src["kv_pos"].shape[1]:
        raise ValueError(
            f"cache_join: max_len mismatch (dst S_buf="
            f"{dst['kv_pos'].shape[1]}, src S_buf={src['kv_pos'].shape[1]})")

    def ins(d, s, axis):
        idx = (slice(None),) * axis + (slot,)
        row = jnp.take(s, 0, axis=axis)
        return d.at[idx].set(row.astype(d.dtype))

    out: Dict = {}
    for key, val in dst.items():
        ax = _slot_axis(key)
        if key in ("cur", "kv_pos"):
            out[key] = ins(val, src[key], ax)
        else:
            out[key] = jax.tree.map(lambda d, s, a=ax: ins(d, s, a),
                                    val, src[key])
    return out


def cache_take(src: Dict, slot: int) -> Dict:
    """Extract slot `slot` of a padded batch cache as a batch-1 cache
    (the inverse of cache_join — used to migrate a request off a drained
    decode instance).  `slot` must be a concrete Python int."""
    def sel(a, axis):
        return jax.lax.slice_in_dim(a, slot, slot + 1, axis=axis)

    out: Dict = {}
    for key, val in src.items():
        ax = _slot_axis(key)
        if key in ("cur", "kv_pos"):
            out[key] = sel(val, ax)
        else:
            out[key] = jax.tree.map(lambda v, a=ax: sel(v, a), val)
    return out


# ---------------------------------------------------------------------------
# Paged (block-table) decode cache
# ---------------------------------------------------------------------------
#
# The padded batch cache above reserves max_len tokens per slot whether or
# not the request ever grows that long.  The paged layout breaks every
# attention cache into physical blocks of `block_size` tokens shared by
# the whole DP unit:
#
#   per attn layer   (stack, num_blocks, block_size, heads...) pools
#   kv_pos           (num_blocks, block_size)   per-token positions
#   block_tab        (slots, nbt) int32         logical -> physical block
#   cur              (slots,) int32             per-slot token counts
#
# A request occupies only ceil(len/block_size) blocks, so a DP's admission
# limit becomes its FREE-BLOCK count (`serving.kv_pool.BlockPool` is the
# host-side allocator) instead of its slot count.  Physical block 0 is the
# reserved null block: -1 table entries map onto it, so inactive slots and
# table padding scatter garbage there without touching live pages, and
# gather-side masking (`attention.gather_paged_pos`) makes its contents
# unobservable.  SSM states, encoder K/V and MoE capacity behave exactly
# as in the padded cache (per-slot; see the continuous-batching note).
# SWA ring caches are not paged (the ring already bounds memory).


def paged_layout(cfg: ModelConfig, max_len: int, block_size: int
                 ) -> Tuple[int, int]:
    """(nbt, block_size) table geometry for a paged cache equivalent to a
    dense max_len cache.  Validates the config supports paging."""
    if not _has_attn_cache(cfg):
        raise ValueError(f"{cfg.name}: no attention cache to page")
    if cfg.attention == AttentionKind.SWA and cfg.sliding_window:
        raise ValueError(
            f"{cfg.name}: SWA ring caches are already bounded — use the "
            f"padded cache")
    if block_size < 1 or max_len % block_size != 0:
        raise ValueError(
            f"max_len={max_len} must be a positive multiple of "
            f"block_size={block_size}")
    return max_len // block_size, block_size


def _paged_entry_struct(cfg: ModelConfig, kind: LayerKind, num_blocks: int,
                        block_size: int, slots: int, dtype, enc_len: int = 0):
    """Like _entry_struct but attention K/V live in block pools; SSM and
    encoder entries keep their per-slot batch layout."""
    hd = cfg.resolved_head_dim
    K = cfg.num_kv_heads
    if kind in (LayerKind.DENSE, LayerKind.MOE):
        if cfg.attention == AttentionKind.MLA:
            m = cfg.mla
            kv = (jnp.zeros((num_blocks, block_size, m.kv_lora_rank), dtype),
                  jnp.zeros((num_blocks, block_size, m.qk_rope_head_dim),
                            dtype))
        else:
            kv = (jnp.zeros((num_blocks, block_size, K, hd), dtype),
                  jnp.zeros((num_blocks, block_size, K, hd), dtype))
        if cfg.is_encoder_decoder:
            enc_kv = (jnp.zeros((slots, enc_len, K, hd), dtype),
                      jnp.zeros((slots, enc_len, K, hd), dtype))
            return (kv, enc_kv)
        return kv
    # SSM (and its enc-dec variant): identical to the padded layout
    return _entry_struct(cfg, kind, slots, 1, dtype, enc_len)


def _cache_groups(cfg: ModelConfig):
    """[(cache key path, LayerKind, stack size)] in layout order."""
    P, pattern, reps = layer_layout(cfg)
    groups = []
    if P:
        groups.append(("prefix", LayerKind.DENSE, P))
    for j, kind in enumerate(pattern):
        groups.append((f"p{j}", kind, reps))
    return groups


def _group_entry(cache: Dict, key: str):
    return cache[key] if key == "prefix" else cache["blocks"][key]


def _set_group_entry(cache: Dict, key: str, val) -> None:
    if key == "prefix":
        cache[key] = val
    else:
        cache["blocks"][key] = val


def init_paged_cache(cfg: ModelConfig, slots: int, num_blocks: int,
                     max_len: int, block_size: int,
                     dtype=jnp.float32) -> Dict:
    """Paged decode cache for one DP unit: `slots` batch rows sharing
    `num_blocks` physical blocks (block 0 reserved as the null block)."""
    nbt, _ = paged_layout(cfg, max_len, block_size)
    enc_len = cfg.encoder_seq_len if cfg.is_encoder_decoder else 0

    def stack(n, kind):
        e = _paged_entry_struct(cfg, kind, num_blocks, block_size, slots,
                                dtype, enc_len)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy(), e)

    cache: Dict = {
        "cur": jnp.zeros((slots,), jnp.int32),
        "kv_pos": jnp.full((num_blocks, block_size), -1, jnp.int32),
        "block_tab": jnp.full((slots, nbt), -1, jnp.int32),
        "blocks": {},
    }
    for key, kind, n in _cache_groups(cfg):
        _set_group_entry(cache, key, stack(n, kind))
    return cache


def _is_attn_kind(kind: LayerKind) -> bool:
    return kind in (LayerKind.DENSE, LayerKind.MOE)


def _split_entry(cfg: ModelConfig, entry):
    """(core, enc_kv-or-None) for one group entry."""
    if cfg.is_encoder_decoder:
        return entry[0], entry[1]
    return entry, None


def _joined_entry(cfg: ModelConfig, core, enc):
    return (core, enc) if cfg.is_encoder_decoder else core


def paged_cache_join(cfg: ModelConfig, dst: Dict, src: Dict, slot,
                     tab_row) -> Dict:
    """Install the batch-1 dense cache `src` (a finished prefill) into the
    paged cache `dst`: its KV tokens are scattered into the physical
    blocks named by `tab_row` ((nbt,) int32, -1 padding) and slot `slot`'s
    table row / token count are set.  `slot` and `tab_row` may be traced
    (one jitted shape regardless of how many blocks are real: padding
    entries scatter into the null block)."""
    nbt = dst["block_tab"].shape[1]
    bs = dst["kv_pos"].shape[1]
    if src["kv_pos"].shape[1] != nbt * bs:
        raise ValueError(
            f"paged_cache_join: src max_len {src['kv_pos'].shape[1]} != "
            f"table capacity {nbt * bs}")
    ids = jnp.maximum(tab_row, 0)

    def scatter_pool(pool, dense):
        # pool (n, N, bs, ...); dense (n, 1, nbt*bs, ...)
        n = pool.shape[0]
        new = dense[:, 0].reshape((n, nbt, bs) + pool.shape[3:])
        return pool.at[:, ids].set(new.astype(pool.dtype))

    def set_slot(arr, dense):
        # per-slot entries: arr (n, slots, ...); dense (n, 1, ...)
        return arr.at[:, slot].set(dense[:, 0].astype(arr.dtype))

    out: Dict = {
        "cur": dst["cur"].at[slot].set(src["cur"][0]),
        "kv_pos": dst["kv_pos"].at[ids].set(
            src["kv_pos"][0].reshape(nbt, bs)),
        "block_tab": dst["block_tab"].at[slot].set(tab_row),
        "blocks": {},
    }
    for key, kind, _ in _cache_groups(cfg):
        d_core, d_enc = _split_entry(cfg, _group_entry(dst, key))
        s_core, s_enc = _split_entry(cfg, _group_entry(src, key))
        if _is_attn_kind(kind):
            core = jax.tree.map(scatter_pool, d_core, s_core)
        else:
            core = jax.tree.map(set_slot, d_core, s_core)
        enc = (jax.tree.map(set_slot, d_enc, s_enc)
               if d_enc is not None else None)
        _set_group_entry(out, key, _joined_entry(cfg, core, enc))
    return out


def paged_cache_take(cfg: ModelConfig, src: Dict, slot: int) -> Dict:
    """Extract slot `slot` of a paged cache as a dense batch-1 cache (the
    inverse of paged_cache_join — watchdog migration and cross-plane
    handoff speak the dense format).  `slot` must be a concrete int."""
    tab_row = src["block_tab"][slot]                       # (nbt,)
    nbt = tab_row.shape[0]
    bs = src["kv_pos"].shape[1]
    ids = jnp.maximum(tab_row, 0)

    def gather_pool(pool):
        # (n, N, bs, ...) -> (n, 1, nbt*bs, ...)
        n = pool.shape[0]
        g = pool[:, ids]                                   # (n, nbt, bs, ...)
        return g.reshape((n, 1, nbt * bs) + pool.shape[3:])

    def take_slot(arr):
        return jax.lax.slice_in_dim(arr, slot, slot + 1, axis=1)

    kv_pos = jnp.where(tab_row[:, None] < 0, -1, src["kv_pos"][ids])
    out: Dict = {
        "cur": jax.lax.slice_in_dim(src["cur"], slot, slot + 1, axis=0),
        "kv_pos": kv_pos.reshape(1, nbt * bs),
        "blocks": {},
    }
    for key, kind, _ in _cache_groups(cfg):
        core, enc = _split_entry(cfg, _group_entry(src, key))
        if _is_attn_kind(kind):
            core = jax.tree.map(gather_pool, core)
        else:
            core = jax.tree.map(take_slot, core)
        enc = jax.tree.map(take_slot, enc) if enc is not None else None
        _set_group_entry(out, key, _joined_entry(cfg, core, enc))
    return out


def paged_cache_clear_slot(cache: Dict, slot) -> Dict:
    """Leave-on-finish for the paged cache: drop slot `slot`'s block-table
    row so its future (garbage) writes route to the null block instead of
    pages the pool may hand to another request."""
    out = dict(cache)
    out["block_tab"] = cache["block_tab"].at[slot].set(-1)
    return out


def paged_decode_step(cfg: ModelConfig, params, token, cache):
    """One decode step over a paged cache.  token (slots, 1) int32;
    returns (logits (slots, V), cache).  Mirrors `decode_step`; only the
    attention cache access is block-table-indirect."""
    from repro.models.blocks import block_decode_paged
    pos = cache["cur"]                                  # (slots,)
    x = jnp.take(params["embed"], token, axis=0)        # (slots,1,D)
    kv_pos = cache["kv_pos"]
    block_tab = cache["block_tab"]
    P, pattern, reps = layer_layout(cfg)
    new_cache: Dict = dict(cache)

    def make_body(kinds, keys):
        def body(carry, xs):
            x, kv_pos = carry
            p_slice, c_slice = xs
            new_entries = {}
            for j, kind in enumerate(kinds):
                x, entry, kv_pos = block_decode_paged(
                    p_slice[keys[j]], x, kind, cfg, c_slice[keys[j]],
                    kv_pos, block_tab, pos)
                new_entries[keys[j]] = entry
            return (x, kv_pos), new_entries
        return body

    if P:
        body = make_body([LayerKind.DENSE], ["s0"])
        (x, kv_pos), ys = jax.lax.scan(
            body, (x, kv_pos),
            ({"s0": params["prefix"]}, {"s0": cache["prefix"]}))
        new_cache["prefix"] = ys["s0"]
    keys = [f"s{j}" for j in range(len(pattern))]
    body = make_body(list(pattern), keys)
    p_stack = {f"s{j}": params["blocks"][f"p{j}"] for j in range(len(pattern))}
    c_stack = {f"s{j}": cache["blocks"][f"p{j}"] for j in range(len(pattern))}
    (x, kv_pos), ys = jax.lax.scan(body, (x, kv_pos), (p_stack, c_stack))
    new_cache["blocks"] = {f"p{j}": ys[f"s{j}"] for j in range(len(pattern))}

    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = logits_from_hidden(cfg, params, x[:, 0])
    new_cache["kv_pos"] = kv_pos
    new_cache["cur"] = pos + 1
    return logits, new_cache


def _require_pageable_prefill(cfg: ModelConfig) -> None:
    if cfg.is_encoder_decoder:
        raise ValueError(
            f"{cfg.name}: page-native prefill is attention-only "
            f"(encoder state has no page representation)")
    for _key, kind, _n in _cache_groups(cfg):
        if not _is_attn_kind(kind):
            raise ValueError(
                f"{cfg.name}: page-native prefill is attention-only "
                f"(per-slot SSM state has no page representation)")


def paged_prefill_step(cfg: ModelConfig, params, tokens, cache, slot):
    """One chunked-prefill step writing DIRECTLY into pool pages: extend
    slot `slot` of a paged cache by the chunk `tokens` ((1, Sc) int32).
    Returns (last-position logits (1, V), cache).

    This is `prefill_chunk` re-based onto the paged layout — the same
    scan structure, with `block_extend_paged` scattering the chunk's KV
    into the slot's physical blocks and attending through the block
    table, so earlier chunks AND shared-prefix pages claimed from the
    prefix cache are read without ever materializing a dense cache.
    `slot` may be traced (one jitted shape per chunk length)."""
    from repro.models.blocks import block_extend_paged
    _require_pageable_prefill(cfg)
    B, Sc = tokens.shape
    pos0 = cache["cur"][slot]                               # scalar
    positions = pos0 + jnp.arange(Sc, dtype=jnp.int32)[None]   # (1, Sc)
    tab_row = cache["block_tab"][slot][None]                # (1, nbt)
    x = jnp.take(params["embed"], tokens, axis=0)
    kv_pos = cache["kv_pos"]
    P, pattern, reps = layer_layout(cfg)
    new_cache: Dict = dict(cache)

    def make_body(kinds, keys):
        def body(carry, xs):
            x, kv_pos = carry
            p_slice, c_slice = xs
            new_entries = {}
            for j, kind in enumerate(kinds):
                x, entry, kv_pos = block_extend_paged(
                    p_slice[keys[j]], x, kind, cfg, c_slice[keys[j]],
                    kv_pos, tab_row, positions)
                new_entries[keys[j]] = entry
            return (x, kv_pos), new_entries
        return body

    if P:
        body = make_body([LayerKind.DENSE], ["s0"])
        (x, kv_pos), ys = jax.lax.scan(
            body, (x, kv_pos),
            ({"s0": params["prefix"]}, {"s0": cache["prefix"]}))
        new_cache["prefix"] = ys["s0"]
    keys = [f"s{j}" for j in range(len(pattern))]
    body = make_body(list(pattern), keys)
    p_stack = {f"s{j}": params["blocks"][f"p{j}"] for j in range(len(pattern))}
    c_stack = {f"s{j}": cache["blocks"][f"p{j}"] for j in range(len(pattern))}
    (x, kv_pos), ys = jax.lax.scan(body, (x, kv_pos), (p_stack, c_stack))
    new_cache["blocks"] = {f"p{j}": ys[f"s{j}"] for j in range(len(pattern))}

    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = logits_from_hidden(cfg, params, x[:, -1])
    new_cache["kv_pos"] = kv_pos
    new_cache["cur"] = cache["cur"].at[slot].set(pos0 + Sc)
    return logits, new_cache


def mixed_step(cfg: ModelConfig, params, token, cache, chunks,
               decode_mask=None):
    """One unified mixed-batch step (Sarathi-style piggybacking): the
    paged decode rows AND one or more chunked-prefill writes share a
    single jitted computation over the same block pool.

    token (slots, 1) int32 — next token per decode slot (garbage rows
    route to the null block exactly as in `paged_decode_step`);
    `chunks` — sequence of `(tokens (1, Sc) int32, slot)` prefill
    chunks applied in order with `paged_prefill_step` semantics;
    `decode_mask` (slots,) bool — True for slots actively decoding.
    Slots that are RESIDENT but still prefilling have real block-table
    rows, so the mask is what keeps the decode half from scribbling a
    garbage token into their pages / bumping their cursors: masked rows
    decode against a -1 table (null block) and keep `cur` unchanged.

    Returns (decode logits (slots, V), tuple of per-chunk last-position
    logits (1, V), cache).  Token-exact vs running `paged_decode_step`
    then each `paged_prefill_step` serially: decode slots and prefill
    slots are disjoint and each sub-step touches only its own pages, so
    composition order is unobservable (property-tested in
    tests/test_mixed_batch.py).  The Pallas decode kernel is unchanged —
    this composes the existing step functions into one XLA program."""
    tab = cache["block_tab"]
    cur = cache["cur"]
    if decode_mask is not None:
        dcache = dict(cache)
        dcache["block_tab"] = jnp.where(decode_mask[:, None], tab, -1)
        logits, cache = paged_decode_step(cfg, params, token, dcache)
        cache["block_tab"] = tab
        cache["cur"] = jnp.where(decode_mask, cur + 1, cur)
    else:
        logits, cache = paged_decode_step(cfg, params, token, cache)
    chunk_logits = []
    for ctoks, slot in chunks:
        lg, cache = paged_prefill_step(cfg, params, ctoks, cache, slot)
        chunk_logits.append(lg)
    return logits, tuple(chunk_logits), cache


def paged_copy_block(cfg: ModelConfig, cache: Dict, src, dst) -> Dict:
    """Copy physical block `src` → `dst` across every attention pool and
    the position map — the copy half of copy-on-write.  The caller owns
    repointing the writing slot's table row at `dst` afterwards."""
    out = dict(cache)
    out["blocks"] = dict(cache["blocks"])
    out["kv_pos"] = cache["kv_pos"].at[dst].set(cache["kv_pos"][src])

    def cp(pool):
        return pool.at[:, dst].set(pool[:, src])

    for key, kind, _ in _cache_groups(cfg):
        if not _is_attn_kind(kind):
            continue
        core, enc = _split_entry(cfg, _group_entry(cache, key))
        core = jax.tree.map(cp, core)
        _set_group_entry(out, key, _joined_entry(cfg, core, enc))
    return out


def paged_gather_blocks(cfg: ModelConfig, cache: Dict, ids) -> Dict:
    """Block-granular handoff payload: the physical rows named by `ids`
    ((nbt,) int32, -1 padding) gathered out of every attention pool, plus
    their kv_pos rows (-1 on padding).  Replaces the dense
    `paged_cache_take` on the prefill→decode path: the payload is sized
    by the PAGES the request holds, not max_len."""
    g = jnp.maximum(ids, 0)
    out: Dict = {
        "kv_pos": jnp.where(ids[:, None] < 0, -1, cache["kv_pos"][g]),
        "blocks": {},
    }

    def gather(pool):
        return pool[:, g]                            # (n, nbt, bs, ...)

    for key, kind, _ in _cache_groups(cfg):
        if not _is_attn_kind(kind):
            continue                                 # page-native: attn-only
        core, _enc = _split_entry(cfg, _group_entry(cache, key))
        _set_group_entry(out, key, jax.tree.map(gather, core))
    return out


def paged_adopt_blocks(cfg: ModelConfig, dst: Dict, payload: Dict, slot,
                       tab_row, copy_mask, clear_mask, cur) -> Dict:
    """Install a `paged_gather_blocks` payload into decode cache `dst`:
    payload block i is scattered into physical block `tab_row[i]` where
    `copy_mask[i]`; rows with `clear_mask[i]` (freshly allocated growth
    blocks with no payload) get their kv_pos reset — a reused block
    inherits stale positions from its previous tenant, and a stale
    pos <= the reader's cursor would alias as valid history.  Rows under
    neither mask are SHARED prefix pages already resident on this DP —
    they are not touched (that is the point of the transfer skip).
    Masked-out scatter traffic routes to the null block."""
    ids_clear = jnp.where(clear_mask, jnp.maximum(tab_row, 0), 0)
    ids_copy = jnp.where(copy_mask, jnp.maximum(tab_row, 0), 0)
    out = dict(dst)
    out["blocks"] = dict(dst["blocks"])
    out["cur"] = dst["cur"].at[slot].set(cur)
    out["block_tab"] = dst["block_tab"].at[slot].set(tab_row)
    kv_pos = dst["kv_pos"].at[ids_clear].set(-1)
    out["kv_pos"] = kv_pos.at[ids_copy].set(payload["kv_pos"])

    def scatter(pool, pay):
        return pool.at[:, ids_copy].set(pay.astype(pool.dtype))

    for key, kind, _ in _cache_groups(cfg):
        if not _is_attn_kind(kind):
            continue
        core, enc = _split_entry(cfg, _group_entry(dst, key))
        core = jax.tree.map(scatter, core, _group_entry(payload, key))
        _set_group_entry(out, key, _joined_entry(cfg, core, enc))
    return out


def paged_clear_rows(cache: Dict, ids) -> Dict:
    """Reset kv_pos for the pool rows named by `ids` ((m,) int32, -1
    padding routes to the null block, harmlessly).  Freshly allocated
    blocks MUST be cleared before a slot attends through them: the rows
    keep stale positions from their previous tenant, and any stale
    pos <= the reader's cursor would alias as valid history."""
    g = jnp.maximum(ids, 0)
    out = dict(cache)
    out["kv_pos"] = cache["kv_pos"].at[g].set(-1)
    return out


# ---------------------------------------------------------------------------
# Encoder (whisper)
# ---------------------------------------------------------------------------

def _run_encoder(cfg: ModelConfig, params, frames: jnp.ndarray,
                 remat: bool = False):
    B, F, D = frames.shape
    x = frames + sinusoid_pos_embedding(F, D)[None].astype(frames.dtype)
    pos = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32), (B, F))

    def body(x, p):
        fn = functools.partial(block_full, kind=LayerKind.DENSE, cfg=cfg,
                               positions=pos, causal=False, use_rope=False)
        y, _, _ = _ckpt(lambda pp, xx: fn(pp, xx), remat)(p, x)
        return y, None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return rms_norm(x, params["enc_ln_f"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def forward_full(cfg: ModelConfig, params, tokens, positions=None, seg=None,
                 embeds=None, want_cache: bool = False, remat: bool = False):
    """Returns (hidden (B,St,D), caches, aux, enc_out).

    tokens: (B, S) int32. embeds: modality-frontend embeddings —
    VLM: prepended patch embeddings; audio: encoder frames.
    """
    B, S = tokens.shape
    D = cfg.d_model
    x = jnp.take(params["embed"], tokens, axis=0)
    enc_out = None
    if cfg.is_encoder_decoder:
        assert embeds is not None, "enc-dec needs frame embeddings"
        enc_out = _run_encoder(cfg, params, embeds.astype(x.dtype), remat)
    elif cfg.num_patch_tokens and embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    St = x.shape[1]
    # §Perf iteration (seq-parallel fallback): when attention heads do not
    # divide the model axis, the launcher maps "attn_seq" -> model axis and
    # the whole layer stack runs sequence-sharded instead of replicated
    # (no-op without an active mesh or when St doesn't divide).
    from repro.distributed.annotate import constrain as _constrain
    x = _constrain(x, "tokens", "attn_seq", None)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(St, dtype=jnp.int32), (B, St))
    if seg is not None and St != S:
        # patch prefix belongs to segment of first text token
        pad_seg = jnp.broadcast_to(seg[:, :1], (B, St - S))
        seg = jnp.concatenate([pad_seg, seg], axis=1)

    P, pattern, reps = layer_layout(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    caches: Dict = {}

    def make_body(kinds):
        def body(carry, p_slice):
            x, aux = carry
            entries = []
            for j, kind in enumerate(kinds):
                pj = p_slice[f"s{j}"]
                fn = functools.partial(
                    block_full, kind=kind, cfg=cfg, positions=positions,
                    seg=seg, causal=True, use_rope=True, enc_out=enc_out)
                x, entry, a = _ckpt(lambda pp, xx: fn(pp, xx), remat)(pj, x)
                entries.append(entry)
                aux = aux + a
            return (x, aux), tuple(entries)
        return body

    if P:
        body = make_body([LayerKind.DENSE])
        (x, aux_total), ys = jax.lax.scan(
            body, (x, aux_total), {"s0": params["prefix"]})
        caches["prefix"] = ys[0]
    body = make_body(list(pattern))
    p_stack = {f"s{j}": params["blocks"][f"p{j}"] for j in range(len(pattern))}
    (x, aux_total), ys = jax.lax.scan(body, (x, aux_total), p_stack)
    caches["blocks"] = {f"p{j}": ys[j] for j in range(len(pattern))}

    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return x, (caches if want_cache else None), aux_total, enc_out


def logits_from_hidden(cfg: ModelConfig, params, x):
    if cfg.tie_embeddings:
        return jnp.einsum("...d,vd->...v", x, params["embed"])
    return jnp.einsum("...d,dv->...v", x, params["lm_head"])


# ---------------------------------------------------------------------------
# Training
# ---------------------------------------------------------------------------

def forward_train(cfg: ModelConfig, params, batch, remat: bool = False):
    """batch: dict(tokens (B,S), targets (B,S; -100 = ignore),
    [embeds (B,P,D) or frames], [seg], [positions]). Returns (loss, metrics)."""
    tokens = batch["tokens"]
    targets = batch["targets"]
    x, _, aux, _ = forward_full(
        cfg, params, tokens,
        positions=batch.get("positions"), seg=batch.get("seg"),
        embeds=batch.get("embeds"), want_cache=False, remat=remat)
    if cfg.num_patch_tokens and batch.get("embeds") is not None:
        x_text = x[:, x.shape[1] - tokens.shape[1]:]
    else:
        x_text = x
    logits = logits_from_hidden(cfg, params, x_text)
    loss, n_tok = _ce_loss(logits, targets)
    metrics = {"ce": loss, "aux": aux, "tokens": n_tok}
    total = loss + cfg.moe.router_aux_weight * aux

    if cfg.mtp_depth > 0 and "mtp" in params:
        mtp = params["mtp"]
        h = rms_norm(x_text[:, :-1], mtp["ln_h"], cfg.norm_eps)
        e = rms_norm(jnp.take(params["embed"], tokens[:, 1:], axis=0),
                     mtp["ln_e"], cfg.norm_eps)
        hm = jnp.einsum("bsd,de->bse", jnp.concatenate([h, e], -1),
                        mtp["proj"])
        B, Sm, _ = hm.shape
        pos = jnp.broadcast_to(jnp.arange(Sm, dtype=jnp.int32), (B, Sm))
        hm, _, mtp_aux, _ = _single_block(cfg, mtp["block"], hm, pos)
        mtp_logits = logits_from_hidden(cfg, params,
                                        rms_norm(hm, params["ln_f"],
                                                 cfg.norm_eps))
        # position t predicts token t+2 => targets shifted once more
        mtp_loss, _ = _ce_loss(mtp_logits[:, :-1], targets[:, 2:])
        metrics["mtp_ce"] = mtp_loss
        total = total + 0.3 * mtp_loss + cfg.moe.router_aux_weight * mtp_aux
    metrics["loss"] = total
    return total, metrics


def _single_block(cfg, p, x, pos):
    kind = cfg.layer_pattern[0]
    y, entry, aux = block_full(p, x, kind, cfg, pos)
    return y, entry, aux, None


def _ce_loss(logits, targets):
    mask = targets >= 0
    tgt = jnp.where(mask, targets, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    n = jnp.maximum(mask.sum(), 1)
    return -(ll * mask).sum() / n, n


def loss_fn(cfg: ModelConfig, params, batch, remat: bool = False):
    loss, _ = forward_train(cfg, params, batch, remat)
    return loss


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------

def prefill(cfg: ModelConfig, params, tokens, embeds=None, lengths=None,
            max_len: Optional[int] = None, seg=None, positions=None,
            cache_dtype=None, remat: bool = False):
    """Run the full prompt, build the decode cache, return last-token logits.

    tokens (B, S); lengths (B,) true per-row lengths (defaults to S).
    Returns (logits (B, V), cache).
    """
    B, S = tokens.shape
    max_len = max_len or cfg.max_seq_len
    cache_dtype = cache_dtype or params["embed"].dtype
    x, caches, aux, enc_out = forward_full(
        cfg, params, tokens, positions=positions, seg=seg, embeds=embeds,
        want_cache=True, remat=remat)
    St = x.shape[1]
    n_prefix = St - S  # patch tokens (VLM)
    if lengths is None:
        lengths = jnp.full((B,), S, jnp.int32)
    full_lengths = lengths + n_prefix

    # last valid hidden state per row
    idx = jnp.clip(full_lengths - 1, 0, St - 1)
    last_h = x[jnp.arange(B), idx]
    logits = logits_from_hidden(cfg, params, last_h)

    cache = init_cache(cfg, B, max_len, cache_dtype)
    S_buf = cache["kv_pos"].shape[1]
    pos_grid = jnp.broadcast_to(jnp.arange(St, dtype=jnp.int32), (B, St))
    valid = pos_grid < full_lengths[:, None]

    if _has_attn_cache(cfg):
        if S_buf >= St:
            kv_pos = jnp.where(valid, pos_grid, -1)
            cache["kv_pos"] = cache["kv_pos"].at[:, :St].set(kv_pos)
        else:
            # SWA ring: slot i holds, per row, the newest VALID position p
            # with p % W == i (rows shorter than St must not see garbage).
            W = S_buf
            last = full_lengths - 1                                 # (B,)
            tail = last[:, None] - ((last[:, None] - jnp.arange(W)) % W)
            cache["kv_pos"] = jnp.where(tail >= 0, tail, -1)        # (B, W)

        def place(buf, new):
            """buf (n,B,S_buf,...), new (n,B,St,...) -> write/ring-gather."""
            if S_buf >= St:
                return jax.lax.dynamic_update_slice_in_dim(
                    buf, new.astype(buf.dtype), 0, axis=2)
            W = S_buf
            last = full_lengths - 1
            tail = last[:, None] - ((last[:, None] - jnp.arange(W)) % W)
            idx = jnp.clip(tail, 0, St - 1)                         # (B, W)
            idx = idx.reshape((1, B, W) + (1,) * (new.ndim - 3))
            return jnp.take_along_axis(new, idx, axis=2).astype(buf.dtype)
    def merge_entry(kind, buf_entry, new_entry):
        if cfg.is_encoder_decoder:
            buf_core, _ = buf_entry
            new_core, enc_kv = new_entry
            return (_merge_core(kind, buf_core, new_core), enc_kv)
        return _merge_core(kind, buf_entry, new_entry)

    def _merge_core(kind, buf_core, new_core):
        if kind in (LayerKind.DENSE, LayerKind.MOE):
            bk, bv = buf_core
            nk, nv = new_core
            return (place(bk, nk), place(bv, nv))
        bs, bc = buf_core
        ns, ncv = new_core
        return (ns.astype(bs.dtype),
                jax.tree.map(lambda n, b: n.astype(b.dtype), ncv, bc))

    P, pattern, reps = layer_layout(cfg)
    if P:
        cache["prefix"] = merge_entry(LayerKind.DENSE, cache["prefix"],
                                      caches["prefix"])
    for j, kind in enumerate(pattern):
        cache["blocks"][f"p{j}"] = merge_entry(
            kind, cache["blocks"][f"p{j}"], caches["blocks"][f"p{j}"])
    cache["cur"] = full_lengths
    return logits, cache


# ---------------------------------------------------------------------------
# Chunked prefill (the paper's C_chunk execution unit)
# ---------------------------------------------------------------------------

def prefill_chunk(cfg: ModelConfig, params, tokens, cache):
    """Extend the cache by one chunk of prompt tokens (B, Sc) — true chunked
    prefill with KV continuation. Returns (logits of last chunk token, cache).

    Whisper note: the encoder must have been run by a prior `prefill` call
    (cross K/V live in the cache); chunks extend only the decoder side.
    """
    from repro.models.blocks import block_extend
    B, Sc = tokens.shape
    pos0 = cache["cur"]                                     # (B,)
    positions = pos0[:, None] + jnp.arange(Sc, dtype=jnp.int32)[None]
    x = jnp.take(params["embed"], tokens, axis=0)
    kv_pos = cache["kv_pos"]
    P, pattern, reps = layer_layout(cfg)
    new_cache: Dict = dict(cache)

    def make_body(kinds, keys):
        def body(carry, xs):
            x, kv_pos = carry
            p_slice, c_slice = xs
            new_entries = {}
            for j, kind in enumerate(kinds):
                x, entry, kv_pos = block_extend(
                    p_slice[keys[j]], x, kind, cfg, c_slice[keys[j]],
                    kv_pos, positions)
                new_entries[keys[j]] = entry
            return (x, kv_pos), new_entries
        return body

    if P:
        body = make_body([LayerKind.DENSE], ["s0"])
        (x, kv_pos), ys = jax.lax.scan(
            body, (x, kv_pos),
            ({"s0": params["prefix"]}, {"s0": cache["prefix"]}))
        new_cache["prefix"] = ys["s0"]
    keys = [f"s{j}" for j in range(len(pattern))]
    body = make_body(list(pattern), keys)
    p_stack = {f"s{j}": params["blocks"][f"p{j}"] for j in range(len(pattern))}
    c_stack = {f"s{j}": cache["blocks"][f"p{j}"] for j in range(len(pattern))}
    (x, kv_pos), ys = jax.lax.scan(body, (x, kv_pos), (p_stack, c_stack))
    new_cache["blocks"] = {f"p{j}": ys[f"s{j}"] for j in range(len(pattern))}

    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = logits_from_hidden(cfg, params, x[:, -1])
    new_cache["kv_pos"] = kv_pos
    new_cache["cur"] = pos0 + Sc
    return logits, new_cache


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def decode_step(cfg: ModelConfig, params, token, cache):
    """One decode step. token (B, 1) int32; returns (logits (B,V), cache)."""
    B = token.shape[0]
    pos = cache["cur"]                                  # (B,)
    x = jnp.take(params["embed"], token, axis=0)        # (B,1,D)
    kv_pos = cache["kv_pos"]
    P, pattern, reps = layer_layout(cfg)
    new_cache: Dict = dict(cache)

    def make_body(kinds, keys):
        def body(carry, xs):
            x, kv_pos = carry
            p_slice, c_slice = xs
            new_entries = {}
            for j, kind in enumerate(kinds):
                x, entry, kv_pos2 = block_decode(
                    p_slice[keys[j]], x, kind, cfg, c_slice[keys[j]],
                    kv_pos, pos)
                new_entries[keys[j]] = entry
                if kv_pos2 is not None:
                    kv_pos = kv_pos2
            return (x, kv_pos), new_entries
        return body

    if P:
        body = make_body([LayerKind.DENSE], ["s0"])
        (x, kv_pos), ys = jax.lax.scan(
            body, (x, kv_pos),
            ({"s0": params["prefix"]}, {"s0": cache["prefix"]}))
        new_cache["prefix"] = ys["s0"]
    keys = [f"s{j}" for j in range(len(pattern))]
    body = make_body(list(pattern), keys)
    p_stack = {f"s{j}": params["blocks"][f"p{j}"] for j in range(len(pattern))}
    c_stack = {f"s{j}": cache["blocks"][f"p{j}"] for j in range(len(pattern))}
    (x, kv_pos), ys = jax.lax.scan(body, (x, kv_pos), (p_stack, c_stack))
    new_cache["blocks"] = {f"p{j}": ys[f"s{j}"] for j in range(len(pattern))}

    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = logits_from_hidden(cfg, params, x[:, 0])
    new_cache["kv_pos"] = kv_pos
    new_cache["cur"] = pos + 1
    return logits, new_cache
