"""Mixture-of-Experts block with sort-based (FLOP-honest) dispatch.

Dispatch uses argsort + capacity slots + gather/scatter so the compiled HLO's
FLOPs equal the ACTIVE expert FLOPs (6·N_active·D accounting in §Roofline
stays honest); token movement is gathers/scatters (bytes, not FLOPs) — the
XLA analogue of the all-to-all dispatch in DP+EP serving systems.

Supports softmax (classic) and sigmoid (DeepSeek-V3) scoring, shared experts,
routed scaling, capacity-factor token dropping, and the load-balance aux loss.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import MoEConfig
from repro.distributed.annotate import constrain
from repro.models.layers import init_linear


def init_moe_params(key, d_model: int, mc: MoEConfig, dtype) -> Dict:
    ks = jax.random.split(key, 6)
    p = {
        "router": init_linear(ks[0], d_model, mc.num_experts, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (mc.num_experts, d_model, mc.d_expert), jnp.float32)
                   / math.sqrt(d_model)).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (mc.num_experts, d_model, mc.d_expert), jnp.float32)
                 / math.sqrt(d_model)).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (mc.num_experts, mc.d_expert, d_model), jnp.float32)
                   / math.sqrt(mc.d_expert)).astype(dtype),
    }
    if mc.score_fn == "sigmoid":
        p["router_bias"] = jnp.zeros((mc.num_experts,), jnp.float32)
    if mc.num_shared:
        p["shared_gate"] = init_linear(ks[4], d_model, mc.num_shared * mc.d_shared, dtype)
        p["shared_up"] = init_linear(ks[4], d_model, mc.num_shared * mc.d_shared, dtype)
        p["shared_down"] = init_linear(ks[5], mc.num_shared * mc.d_shared, d_model, dtype)
    return p


def _capacity_axis():
    """'tokens' if the token axes are disjoint from the expert axes."""
    from repro.distributed import annotate as _ann
    ctx = _ann.active()
    if ctx is None:
        return None
    amap = ctx["map"]
    tok = amap.get("tokens") or ()
    tok = {tok} if isinstance(tok, str) else set(tok)
    ep = amap.get("experts") or ()
    ep = {ep} if isinstance(ep, str) else set(ep)
    return None if (tok & ep) else "tokens"


def route(x2d: jnp.ndarray, params: Dict, mc: MoEConfig
          ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Token-choice top-k routing. x2d: (T, D) -> weights/ids (T, k), probs (T, E)."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32), params["router"])
    if mc.score_fn == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        sel = scores + params.get("router_bias", 0.0)  # aux-loss-free bias (DS-V3)
        top_w, top_e = jax.lax.top_k(sel, mc.top_k)
        # weights from raw scores at selected experts, normalized
        top_w = jnp.take_along_axis(scores, top_e, axis=-1)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
        top_w = top_w * mc.routed_scaling
        probs = scores / jnp.maximum(scores.sum(-1, keepdims=True), 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_e = jax.lax.top_k(probs, mc.top_k)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    return top_w, top_e, probs


def aux_loss(probs: jnp.ndarray, top_e: jnp.ndarray, num_experts: int) -> jnp.ndarray:
    """Switch-style load-balance loss: E · Σ_e f_e · P_e."""
    T = probs.shape[0]
    counts = jnp.zeros((num_experts,), jnp.float32).at[top_e.reshape(-1)].add(1.0)
    f = counts / jnp.maximum(T * top_e.shape[-1], 1)
    p = probs.mean(axis=0)
    return num_experts * jnp.sum(f * p)


def moe_block(x: jnp.ndarray, params: Dict, mc: MoEConfig,
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Apply MoE. x: (B, S, D) or (T, D). Returns (out, aux_loss).

    Under an annotate.activate(..., ep_shard_map=True) context this
    delegates to the explicit all-to-all EP path when the shapes divide."""
    from repro.distributed import annotate as _ann
    ctx = _ann.active()
    if ctx is not None and ctx.get("ep"):
        import numpy as np
        mesh = ctx["mesh"]
        amap = ctx["map"]
        tok = amap.get("tokens") or ()
        tok = (tok,) if isinstance(tok, str) else tuple(tok)
        ep = amap.get("experts") or ()
        ep = (ep,) if isinstance(ep, str) else tuple(ep)
        T = int(np.prod(x.shape[:-1]))
        n_dev = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
        G = int(np.prod([mesh.shape[a] for a in ep])) if ep else 0
        if (ep and G and mc.num_experts % G == 0 and T % n_dev == 0
                and T // n_dev >= 1):
            from repro.models.moe_ep import moe_block_ep
            return moe_block_ep(x, params, mc, mesh, tok, ep)
    orig_shape = x.shape
    x2d = x.reshape(-1, x.shape[-1])
    T, D = x2d.shape
    E, k = mc.num_experts, mc.top_k

    top_w, top_e, probs = route(x2d, params, mc)
    laux = aux_loss(probs, top_e, E)

    # capacity per expert
    C = max(int(math.ceil(T * k / E * mc.capacity_factor)), 1)

    # ---- sort-based dispatch ----
    # §Perf iteration 1 (see EXPERIMENTS.md): dispatch/combine are expressed
    # as SMALL integer-index exchanges plus big gathers whose outputs carry
    # explicit sharding annotations ("experts" / "tokens"). The original
    # formulation scattered through a flat (E·C+1, D) buffer whose
    # data-dependent indices made GSPMD replicate 240 GB f32 intermediates
    # and all-reduce them (28 TB/device for DeepSeek-V3 prefill_32k).
    flat_e = top_e.reshape(-1)                       # (T*k,)
    order = jnp.argsort(flat_e, stable=True)         # token-major within expert
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    pos_in_e = jnp.arange(T * k) - first[sorted_e]
    keep = pos_in_e < C
    c_idx = jnp.where(keep, pos_in_e, C)              # column C = drop bin
    tok = order // k                                  # source token per flat slot

    # (E, C+1) int32 routing table: slot -> source token (T = padding row)
    tok_buf = jnp.full((E, C + 1), T, jnp.int32).at[sorted_e, c_idx].set(
        jnp.where(keep, tok, T))
    x_pad = jnp.concatenate([x2d, jnp.zeros((1, D), x2d.dtype)])
    h = x_pad[tok_buf[:, :C]]                         # (E, C, D) gather
    # capacity dim sharded over the token axes (when disjoint from the
    # expert axes): otherwise expert compute replicates across data —
    # measured as 16× over-compute on jamba train (§Perf iteration 3).
    c_axis = _capacity_axis()
    h = constrain(h, "experts", c_axis, None)

    # ---- expert computation (active FLOPs only) ----
    g = jnp.einsum("ecd,edf->ecf", h, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", h, params["w_up"])
    act = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * u
    y = jnp.einsum("ecf,efd->ecd", act, params["w_down"])
    y = constrain(y, "experts", c_axis, None)

    # ---- combine: pure per-token gather (no scatter-add) ----
    pos_tk = jnp.zeros((T * k,), jnp.int32).at[order].set(c_idx).reshape(T, k)
    y_pad = jnp.concatenate([y, jnp.zeros((E, 1, D), y.dtype)], axis=1)
    contrib = y_pad[top_e, pos_tk]                    # (T, k, D)
    contrib = constrain(contrib, "tokens", None, None)
    out = (contrib * top_w[..., None].astype(y.dtype)).sum(axis=1)

    if mc.num_shared:
        gs = jnp.einsum("td,df->tf", x2d, params["shared_gate"])
        us = jnp.einsum("td,df->tf", x2d, params["shared_up"])
        hs = jax.nn.silu(gs.astype(jnp.float32)).astype(x2d.dtype) * us
        out = out + jnp.einsum("tf,fd->td", hs, params["shared_down"])

    return out.reshape(orig_shape), laux


def moe_block_dense_reference(x: jnp.ndarray, params: Dict, mc: MoEConfig
                              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dense (all-experts) oracle — O(E) FLOPs, used only in tests to verify
    the sort-based dispatch (identical when no token is dropped)."""
    orig_shape = x.shape
    x2d = x.reshape(-1, x.shape[-1])
    top_w, top_e, probs = route(x2d, params, mc)
    laux = aux_loss(probs, top_e, mc.num_experts)
    g = jnp.einsum("td,edf->tef", x2d, params["w_gate"])
    u = jnp.einsum("td,edf->tef", x2d, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x2d.dtype) * u
    y = jnp.einsum("tef,efd->ted", h, params["w_down"])       # (T, E, D)
    w_full = jnp.zeros((x2d.shape[0], mc.num_experts), y.dtype)
    w_full = jax.vmap(lambda w, e, r: w.at[e].add(r))(w_full, top_e, top_w.astype(y.dtype))
    out = jnp.einsum("te,ted->td", w_full, y)
    if mc.num_shared:
        gs = jnp.einsum("td,df->tf", x2d, params["shared_gate"])
        us = jnp.einsum("td,df->tf", x2d, params["shared_up"])
        hs = jax.nn.silu(gs.astype(jnp.float32)).astype(x2d.dtype) * us
        out = out + jnp.einsum("tf,fd->td", hs, params["shared_down"])
    return out.reshape(orig_shape), laux
