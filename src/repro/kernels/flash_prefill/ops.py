"""Public jit'd wrapper for the flash_prefill kernel.

On CPU (this container) the kernel body executes in interpret mode; on TPU
it lowers through Mosaic with the BlockSpec VMEM tiling in kernel.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_prefill.kernel import flash_prefill_pallas


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_kv", "interpret"))
def flash_prefill(q, k, v, q_pos, kv_pos, q_seg, kv_seg, *,
                  causal: bool = True, window: int = 0,
                  block_q: int = 128, block_kv: int = 256,
                  interpret: bool | None = None):
    if interpret is None:
        interpret = _on_cpu()
    return flash_prefill_pallas(
        q, k, v, q_pos, kv_pos, q_seg, kv_seg,
        causal=causal, window=window, block_q=block_q, block_kv=block_kv,
        interpret=interpret)
