from repro.kernels.flash_prefill.ops import flash_prefill

__all__ = ["flash_prefill"]
