"""Packed-varlen flash-attention Pallas TPU kernel.

This is the chunked-prefill compute unit (paper §4.2: C_chunk): a chunk
packs multiple requests' prompt segments; masking is causal WITHIN a segment
(segment ids + per-segment positions), with optional sliding window.

TPU schedule: grid (batch·kv_head, q_blocks, kv_blocks), kv innermost
("arbitrary" semantics) so the online-softmax running state (m, l, acc)
persists in VMEM scratch across kv iterations. BlockSpecs tile
q/k/v (block_q × head_dim) / (block_kv × head_dim) into VMEM; block sizes
default to 128/256 to keep MXU matmul dims at lane multiples of 128.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128


def _flash_kernel(
    q_ref, k_ref, v_ref, qpos_ref, kvpos_ref, qseg_ref, kvseg_ref,  # inputs
    o_ref,                                                          # outputs
    m_scr, l_scr, acc_scr,                                          # scratch
    *, scale: float, causal: bool, window: int, kv_blocks: int,
):
    ikv = pl.program_id(2)

    @pl.when(ikv == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]                    # (G·bq, hd)  — G query heads folded
    k = k_ref[0]                       # (bk, hd)
    v = v_ref[0]
    qpos = qpos_ref[0]                 # (bq,)
    kvpos = kvpos_ref[0]               # (bk,)
    qseg = qseg_ref[0]
    kvseg = kvseg_ref[0]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale     # (G·bq, bk)

    bq = qpos.shape[0]
    G = q.shape[0] // bq
    qpos_f = jnp.tile(qpos, (G,))
    qseg_f = jnp.tile(qseg, (G,))
    mask = (kvpos[None, :] >= 0) & (kvseg[None, :] == qseg_f[:, None])
    mask &= qseg_f[:, None] >= 0
    if causal:
        mask &= qpos_f[:, None] >= kvpos[None, :]
    if window > 0:
        mask &= (qpos_f[:, None] - kvpos[None, :]) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_scr[:, 0] * alpha + p.sum(axis=-1)
    acc = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    m_scr[:, 0] = m_new
    l_scr[:, 0] = l_new
    acc_scr[...] = acc

    @pl.when(ikv == kv_blocks - 1)
    def _finalize():
        l = l_scr[:, 0]
        safe = jnp.maximum(l, 1e-30)
        out = jnp.where(l[:, None] > 0, acc_scr[...] / safe[:, None], 0.0)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def flash_prefill_pallas(
    q: jnp.ndarray,            # (B, Sq, H, hd)
    k: jnp.ndarray,            # (B, Skv, K, hd)
    v: jnp.ndarray,
    q_pos: jnp.ndarray,        # (B, Sq) int32
    kv_pos: jnp.ndarray,       # (B, Skv)
    q_seg: jnp.ndarray,        # (B, Sq)   (-1 = pad)
    kv_seg: jnp.ndarray,       # (B, Skv)
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_kv: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Sq, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    G = H // K
    scale = hd ** -0.5

    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    assert Sq % block_q == 0 and Skv % block_kv == 0, \
        "pad sequences to block multiples"
    nq, nkv = Sq // block_q, Skv // block_kv

    # layout: fold G into rows of the q tile -> (B, K, nq, G·bq, hd)
    qr = q.reshape(B, Sq, K, G, hd).transpose(0, 2, 1, 3, 4)  # B,K,Sq,G,hd
    qr = qr.reshape(B, K, nq, block_q, G, hd).transpose(0, 1, 2, 4, 3, 5)
    qr = qr.reshape(B * K, nq, G * block_q, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(B * K, Skv, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(B * K, Skv, hd)

    qpos_r = jnp.repeat(q_pos[:, None], K, 1).reshape(B * K, Sq)
    kvpos_r = jnp.repeat(kv_pos[:, None], K, 1).reshape(B * K, Skv)
    qseg_r = jnp.repeat(q_seg[:, None], K, 1).reshape(B * K, Sq)
    kvseg_r = jnp.repeat(kv_seg[:, None], K, 1).reshape(B * K, Skv)

    grid = (B * K, nq, nkv)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        kv_blocks=nkv)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G * block_q, hd), lambda b, iq, ik: (b, iq, 0, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda b, iq, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda b, iq, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_q), lambda b, iq, ik: (b, iq)),
            pl.BlockSpec((1, block_kv), lambda b, iq, ik: (b, ik)),
            pl.BlockSpec((1, block_q), lambda b, iq, ik: (b, iq)),
            pl.BlockSpec((1, block_kv), lambda b, iq, ik: (b, ik)),
        ],
        out_specs=pl.BlockSpec((1, 1, G * block_q, hd),
                               lambda b, iq, ik: (b, iq, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * K, nq, G * block_q, hd), q.dtype),
        scratch_shapes=[
            # m, l: (rows, 1) f32; acc: (rows, hd) f32 — persist across the
            # kv grid axis (innermost, sequential on TPU)
            pltpu.VMEM((G * block_q, 1), jnp.float32),
            pltpu.VMEM((G * block_q, 1), jnp.float32),
            pltpu.VMEM((G * block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr, qpos_r, kvpos_r, qseg_r, kvseg_r)

    # un-fold: (B·K, nq, G·bq, hd) -> (B, Sq, H, hd)
    out = out.reshape(B, K, nq, G, block_q, hd).transpose(0, 2, 4, 1, 3, 5)
    out = out.reshape(B, Sq, K, G, hd).reshape(B, Sq, H, hd)
    return out
