"""Pure-jnp oracle for the flash_prefill kernel (identical semantics)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_prefill_ref(q, k, v, q_pos, kv_pos, q_seg, kv_seg,
                      causal: bool = True, window: int = 0):
    """q (B,Sq,H,hd), k/v (B,Skv,K,hd). Naive masked softmax attention with
    packed-segment semantics: attend iff same segment, kv valid, causal
    within segment (by absolute position), optional sliding window."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    scale = hd ** -0.5
    qg = q.reshape(B, Sq, K, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32) * scale
    mask = (kv_pos[:, None, :] >= 0) & (q_seg[:, :, None] == kv_seg[:, None, :])
    mask &= q_seg[:, :, None] >= 0
    if causal:
        mask &= q_pos[:, :, None] >= kv_pos[:, None, :]
    if window > 0:
        mask &= (q_pos[:, :, None] - kv_pos[:, None, :]) < window
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask.any(-1)[:, None, None, :, None], p, 0.0)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v)
    return o.reshape(B, Sq, H, hd)
