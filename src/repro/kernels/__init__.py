"""Pallas TPU kernels for the serving hot spots.

flash_prefill/    — packed-varlen flash attention (segment-id masked): the
                    compute unit behind the paper's C_chunk capacity model.
decode_attention/ — GQA decode against the KV cache (memory-bound sweep).
ssd_scan/         — Mamba2 SSD intra-chunk kernel (hybrid/SSM archs).

Each kernel package ships kernel.py (pl.pallas_call + BlockSpec), ops.py
(jit'd public wrapper; interpret=True on CPU), and ref.py (pure-jnp oracle
swept against the kernel in tests).
"""
