from repro.kernels.ssd_scan.ops import ssd_chunk_kernel_apply

__all__ = ["ssd_chunk_kernel_apply"]
