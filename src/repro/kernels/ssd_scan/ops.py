"""Public jit'd wrapper for the SSD intra-chunk kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssd_scan.kernel import ssd_chunk_pallas


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_kernel_apply(x, dt, A, Bm, Cm, *, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return ssd_chunk_pallas(x, dt, A, Bm, Cm, interpret=interpret)
