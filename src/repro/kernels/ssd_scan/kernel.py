"""Mamba2 SSD intra-chunk Pallas TPU kernel.

Computes, per (batch, chunk, head) grid cell, the two MXU-friendly pieces of
the SSD chunked algorithm (arXiv:2405.21060 §6):

    y_diag[q,p] = Σ_k  (C_q·B_k) · exp(Ā_q − Ā_k) · dt_k · x[k,p]   (k ≤ q)
    state[p,n]  = Σ_k  exp(Ā_last − Ā_k) · dt_k · B_k[n] · x[k,p]

where Ā is the within-chunk cumulative sum of dt·A for that head. The
inter-chunk linear recurrence (tiny, sequential) remains a jax.lax.scan in
repro.models.mamba — the kernel replaces the quadratic/matmul-heavy part.

Tiling: one (Q × hp) x-tile, (Q × ds) B/C tiles per grid cell; Q=chunk size
(≤256) and hp/ds are 64/128 ⇒ all matmul dims are MXU-aligned multiples.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,
                y_ref, state_ref):
    x = x_ref[0, 0].astype(jnp.float32)        # (Q, hp)
    dt = dt_ref[0, 0]                           # (Q,) f32
    A = a_ref[0]                                # scalar (per head)
    Bm = b_ref[0, 0].astype(jnp.float32)        # (Q, ds)
    Cm = c_ref[0, 0].astype(jnp.float32)        # (Q, ds)

    dA = dt * A                                 # (Q,)
    dA_cum = jnp.cumsum(dA)                     # inclusive
    Q = x.shape[0]
    rel = dA_cum[:, None] - dA_cum[None, :]     # (Q, Q)
    causal = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.exp(jnp.where(causal, rel, -1e30))  # mask pre-exp (overflow)
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q,Q)
    att = CB * L * dt[None, :]
    y = jax.lax.dot_general(att, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q,hp)
    w = jnp.exp(dA_cum[-1] - dA_cum) * dt                         # (Q,)
    state = jax.lax.dot_general(Bm * w[:, None], x,
                                (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (ds,hp)
    y_ref[0, 0] = y.astype(y_ref.dtype)
    state_ref[0, 0] = state.astype(state_ref.dtype)


def ssd_chunk_pallas(
    x: jnp.ndarray,      # (B, nc, Q, nh, hp)
    dt: jnp.ndarray,     # (B, nc, Q, nh) f32 (softplus'd)
    A: jnp.ndarray,      # (nh,) f32 negative
    Bm: jnp.ndarray,     # (B, nc, Q, ds)   (n_groups = 1, broadcast to heads)
    Cm: jnp.ndarray,     # (B, nc, Q, ds)
    *,
    interpret: bool = False,
):
    """Returns (y_diag (B,nc,Q,nh,hp) f32, states (B,nc,nh,ds,hp) f32)."""
    B, nc, Q, nh, hp = x.shape
    ds = Bm.shape[-1]
    xr = x.transpose(0, 1, 3, 2, 4).reshape(B * nc, nh, Q, hp)
    dtr = dt.transpose(0, 1, 3, 2).reshape(B * nc, nh, Q)
    br = jnp.broadcast_to(Bm[:, :, None], (B, nc, nh, Q, ds)
                          ).reshape(B * nc, nh, Q, ds)
    cr = jnp.broadcast_to(Cm[:, :, None], (B, nc, nh, Q, ds)
                          ).reshape(B * nc, nh, Q, ds)

    y, st = pl.pallas_call(
        _ssd_kernel,
        grid=(B * nc, nh),
        in_specs=[
            pl.BlockSpec((1, 1, Q, hp), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, Q), lambda b, h: (b, h, 0)),
            pl.BlockSpec((1,), lambda b, h: (h,)),
            pl.BlockSpec((1, 1, Q, ds), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, Q, ds), lambda b, h: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, hp), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, ds, hp), lambda b, h: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * nc, nh, Q, hp), jnp.float32),
            jax.ShapeDtypeStruct((B * nc, nh, ds, hp), jnp.float32),
        ],
        interpret=interpret,
    )(xr, dtr, A.astype(jnp.float32), br, cr)
    y = y.reshape(B, nc, nh, Q, hp).transpose(0, 1, 3, 2, 4)
    st = st.reshape(B, nc, nh, ds, hp)
    return y, st
