"""Pure-jnp oracle for the SSD intra-chunk kernel."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def ssd_chunk_ref(x, dt, A, Bm, Cm):
    """Same contract as ssd_chunk_pallas (n_groups=1 broadcast).
    x (B,nc,Q,nh,hp); dt (B,nc,Q,nh); A (nh,); Bm/Cm (B,nc,Q,ds)."""
    B, nc, Q, nh, hp = x.shape
    x32 = x.astype(jnp.float32)
    B32 = Bm.astype(jnp.float32)
    C32 = Cm.astype(jnp.float32)
    dA = dt * A                                       # (B,nc,Q,nh)
    dA_cum = jnp.cumsum(dA, axis=2)
    rel = dA_cum[:, :, :, None, :] - dA_cum[:, :, None, :, :]  # (B,nc,Q,Q,nh)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.exp(jnp.where(causal[None, None, :, :, None], rel, -1e30))
    CB = jnp.einsum("bcqn,bckn->bcqk", C32, B32)
    att = CB[..., None] * L * dt[:, :, None, :, :]
    y = jnp.einsum("bcqkh,bckhp->bcqhp", att, x32)
    w = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum) * dt   # (B,nc,Q,nh)
    st = jnp.einsum("bckh,bckn,bckhp->bchnp", w, B32, x32)
    return y, st
