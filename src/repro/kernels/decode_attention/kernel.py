"""GQA decode-attention Pallas TPU kernel.

One new token per sequence attends to its KV cache — the decode phase's
memory-bound hot loop (it reads the entire cache every step; this is the K_i
term that Algorithm 3 balances). Grid (batch·kv_head, kv_blocks) streams the
cache through VMEM in (block_kv × head_dim) tiles; the online-softmax state
for the G=H/K query heads rides in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, kvpos_ref, pos_ref,
                   o_ref, m_scr, l_scr, acc_scr,
                   *, scale: float, window: int, kv_blocks: int):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                  # (G, hd)
    k = k_ref[0]                  # (bk, hd)
    v = v_ref[0]
    kvpos = kvpos_ref[0]          # (bk,)
    pos = pos_ref[0, 0]           # scalar

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    valid = (kvpos >= 0) & (kvpos <= pos)
    if window > 0:
        valid &= (pos - kvpos) < window
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_scr[:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.where(valid[None, :], jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[:, 0] = l_scr[:, 0] * alpha + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[:, 0] = m_new

    @pl.when(ik == kv_blocks - 1)
    def _finalize():
        l = l_scr[:, 0]
        out = jnp.where(l[:, None] > 0,
                        acc_scr[...] / jnp.maximum(l, 1e-30)[:, None], 0.0)
        o_ref[0] = out.astype(o_ref.dtype)


def _paged_decode_kernel(tab_ref, pos_ref, q_ref, k_ref, v_ref, kvpos_ref,
                         o_ref, m_scr, l_scr, acc_scr,
                         *, scale: float, window: int, nbt: int, K: int):
    """Block-table-aware decode attention.

    Grid (B·K, nbt): program (r, j) visits logical block j of row r//K.
    The physical block id comes from the scalar-prefetched block table —
    the BlockSpec index maps resolve `tab[b, j]` BEFORE the body runs, so
    the DMA streams exactly the row's own pages through VMEM (unset
    entries clamp to physical block 0, the null block, and are masked
    out via the prefetched table value)."""
    r = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                  # (G, hd)
    k = k_ref[0, :, 0]            # (bs, hd)
    v = v_ref[0, :, 0]
    kvpos = kvpos_ref[0]          # (bs,)
    pos = pos_ref[r // K]         # scalar
    live = tab_ref[r // K, j] >= 0

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    valid = (kvpos >= 0) & (kvpos <= pos) & live
    if window > 0:
        valid &= (pos - kvpos) < window
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_scr[:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.where(valid[None, :], jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[:, 0] = l_scr[:, 0] * alpha + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[:, 0] = m_new

    @pl.when(j == nbt - 1)
    def _finalize():
        l = l_scr[:, 0]
        out = jnp.where(l[:, None] > 0,
                        acc_scr[...] / jnp.maximum(l, 1e-30)[:, None], 0.0)
        o_ref[0] = out.astype(o_ref.dtype)


def paged_decode_attention_pallas(
    q: jnp.ndarray,           # (B, H, hd) — one token per row
    k_pool: jnp.ndarray,      # (N, bs, K, hd) — physical block pool
    v_pool: jnp.ndarray,
    kv_pos_pool: jnp.ndarray,  # (N, bs) int32, -1 = empty
    block_tab: jnp.ndarray,   # (B, nbt) int32, -1 = unset (null block)
    pos: jnp.ndarray,         # (B,) int32 current positions
    *,
    window: int = 0,
    interpret: bool = False,
) -> jnp.ndarray:
    B, H, hd = q.shape
    N, bs, K, _ = k_pool.shape
    G = H // K
    nbt = block_tab.shape[1]
    scale = hd ** -0.5

    qr = q.reshape(B, K, G, hd).reshape(B * K, G, hd)
    kernel = functools.partial(_paged_decode_kernel, scale=scale,
                               window=window, nbt=nbt, K=K)

    def blk(r, j, tab, _pos):
        return (jnp.maximum(tab[r // K, j], 0), 0, r % K, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,            # block table + positions
        grid=(B * K, nbt),
        in_specs=[
            pl.BlockSpec((1, G, hd), lambda r, j, tab, _pos: (r, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd), blk),
            pl.BlockSpec((1, bs, 1, hd), blk),
            pl.BlockSpec((1, bs),
                         lambda r, j, tab, _pos:
                         (jnp.maximum(tab[r // K, j], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda r, j, tab, _pos: (r, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * K, G, hd), q.dtype),
        interpret=interpret,
    )(block_tab, pos, qr, k_pool, v_pool, kv_pos_pool)
    return out.reshape(B, K, G, hd).reshape(B, H, hd)


def decode_attention_pallas(
    q: jnp.ndarray,           # (B, H, hd) — one token per row
    k_cache: jnp.ndarray,     # (B, S, K, hd)
    v_cache: jnp.ndarray,
    kv_pos: jnp.ndarray,      # (B, S) int32, -1 = empty
    pos: jnp.ndarray,         # (B,) int32 current positions
    *,
    window: int = 0,
    block_kv: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    B, H, hd = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = hd ** -0.5
    block_kv = min(block_kv, S)
    assert S % block_kv == 0, "cache length must be a block multiple"
    nkv = S // block_kv

    qr = q.reshape(B, K, G, hd).reshape(B * K, G, hd)
    kr = k_cache.transpose(0, 2, 1, 3).reshape(B * K, S, hd)
    vr = v_cache.transpose(0, 2, 1, 3).reshape(B * K, S, hd)
    kvpos_r = jnp.repeat(kv_pos[:, None], K, 1).reshape(B * K, S)
    pos_r = jnp.repeat(pos[:, None], K, 1).reshape(B * K, 1)

    kernel = functools.partial(_decode_kernel, scale=scale, window=window,
                               kv_blocks=nkv)
    out = pl.pallas_call(
        kernel,
        grid=(B * K, nkv),
        in_specs=[
            pl.BlockSpec((1, G, hd), lambda b, ik: (b, 0, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda b, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda b, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_kv), lambda b, ik: (b, ik)),
            pl.BlockSpec((1, 1), lambda b, ik: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda b, ik: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * K, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr, kvpos_r, pos_r)
    return out.reshape(B, K, G, hd).reshape(B, H, hd)
