"""Pure-jnp oracle for the decode_attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_decode_attention_ref(q, k_pool, v_pool, kv_pos_pool, block_tab,
                               pos, window: int = 0):
    """Dense block-gather oracle for the paged kernel: materialise each
    row's blocks contiguously, then run the flat reference.  q (B,H,hd);
    pools (N,bs,K,hd); kv_pos_pool (N,bs); block_tab (B,nbt); pos (B,)."""
    B, nbt = block_tab.shape
    bs = k_pool.shape[1]
    safe = jnp.maximum(block_tab, 0)
    k = k_pool[safe].reshape((B, nbt * bs) + k_pool.shape[2:])
    v = v_pool[safe].reshape((B, nbt * bs) + v_pool.shape[2:])
    kv_pos = jnp.where(block_tab[..., None] < 0, -1,
                       kv_pos_pool[safe]).reshape(B, nbt * bs)
    return decode_attention_ref(q, k, v, kv_pos, pos, window)


def decode_attention_ref(q, k_cache, v_cache, kv_pos, pos, window: int = 0):
    """q (B,H,hd); caches (B,S,K,hd); kv_pos (B,S); pos (B,)."""
    B, H, hd = q.shape
    K = k_cache.shape[2]
    G = H // K
    scale = hd ** -0.5
    qg = q.reshape(B, K, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache).astype(jnp.float32) * scale
    valid = (kv_pos >= 0) & (kv_pos <= pos[:, None])
    if window > 0:
        valid &= (pos[:, None] - kv_pos) < window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(valid.any(-1)[:, None, None, None], p, 0.0)
    o = jnp.einsum("bkgs,bskh->bkgh", p.astype(v_cache.dtype), v_cache)
    return o.reshape(B, H, hd)
