"""Public jit'd wrapper for the decode_attention kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.decode_attention.kernel import (
    decode_attention_pallas, paged_decode_attention_pallas,
)


@functools.partial(jax.jit, static_argnames=("window", "block_kv",
                                             "interpret"))
def decode_attention(q, k_cache, v_cache, kv_pos, pos, *,
                     window: int = 0, block_kv: int = 256,
                     interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return decode_attention_pallas(
        q, k_cache, v_cache, kv_pos, pos,
        window=window, block_kv=block_kv, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_attention(q, k_pool, v_pool, kv_pos_pool, block_tab, pos, *,
                           window: int = 0, interpret: bool | None = None):
    """Block-table-aware decode attention: the kv blocks are streamed by
    physical id resolved from the scalar-prefetched table (no dense
    gather materialisation)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return paged_decode_attention_pallas(
        q, k_pool, v_pool, kv_pos_pool, block_tab, pos,
        window=window, interpret=interpret)
