"""Public jit'd wrapper for the decode_attention kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.decode_attention.kernel import decode_attention_pallas


@functools.partial(jax.jit, static_argnames=("window", "block_kv",
                                             "interpret"))
def decode_attention(q, k_cache, v_cache, kv_pos, pos, *,
                     window: int = 0, block_kv: int = 256,
                     interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return decode_attention_pallas(
        q, k_cache, v_cache, kv_pos, pos,
        window=window, block_kv=block_kv, interpret=interpret)
