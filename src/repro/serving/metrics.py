"""Serving metrics: TTFT / queuing / utilization / decode balance."""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

from repro.core.types import Request


def percentile(xs: Sequence[float], q: float) -> float:
    if not xs:
        return float("nan")
    v = sorted(xs)
    rank = (len(v) - 1) * q / 100.0
    lo = int(rank)
    hi = min(lo + 1, len(v) - 1)
    return v[lo] + (v[hi] - v[lo]) * (rank - lo)


def mean(xs: Sequence[float]) -> float:
    return sum(xs) / len(xs) if xs else float("nan")


def std(xs: Sequence[float]) -> float:
    if len(xs) < 2:
        return 0.0
    m = mean(xs)
    return math.sqrt(sum((x - m) ** 2 for x in xs) / (len(xs) - 1))


def goodput_by_class(requests: Sequence[Request],
                     default_slo: Optional[float] = None
                     ) -> Dict[str, float]:
    """SLO-attained fraction per priority class.  The denominator is the
    WHOLE offered class — rejected and unfinished requests count against
    goodput, so shedding load never looks like serving it.  A request's
    own `slo_e2e` wins over `default_slo` (see Request.slo_attained)."""
    total: Dict[str, int] = {}
    attained: Dict[str, int] = {}
    for r in requests:
        total[r.slo_class] = total.get(r.slo_class, 0) + 1
        if r.slo_attained(default_slo):
            attained[r.slo_class] = attained.get(r.slo_class, 0) + 1
    return {c: attained.get(c, 0) / n for c, n in sorted(total.items())}


@dataclasses.dataclass
class PrefillReport:
    n: int
    ttft_mean: float
    ttft_p50: float
    ttft_p99: float
    queue_mean: float            # scheduler-side queueing
    device_queue_mean: float     # HOL blocking inside the engine
    chunk_util: float
    qps_served: float
    rejected: int = 0

    def row(self) -> str:
        return (f"n={self.n} ttft={self.ttft_mean*1000:.1f}ms "
                f"p99={self.ttft_p99*1000:.1f}ms "
                f"devq={self.device_queue_mean*1000:.1f}ms "
                f"util={self.chunk_util*100:.1f}% qps={self.qps_served:.1f}")


def prefill_report(requests: Sequence[Request], duration: float,
                   chunk_util: float, rejected: int = 0) -> PrefillReport:
    done = [r for r in requests if r.first_token_time is not None]
    ttfts = [r.ttft for r in done]
    queues = [r.queueing_delay for r in done if r.queueing_delay is not None]
    devq = [r.device_queue_delay for r in done
            if r.device_queue_delay is not None]
    return PrefillReport(
        n=len(done),
        ttft_mean=mean(ttfts), ttft_p50=percentile(ttfts, 50),
        ttft_p99=percentile(ttfts, 99),
        queue_mean=mean(queues) if queues else 0.0,
        device_queue_mean=mean(devq) if devq else 0.0,
        chunk_util=chunk_util,
        qps_served=len(done) / duration if duration > 0 else float("nan"),
        rejected=rejected,
    )


@dataclasses.dataclass
class DecodeReport:
    tokens_generated: int
    duration: float
    throughput: float            # tokens / s
    kv_std_mean: float           # time-averaged std of per-DP KV loads
    kv_band: tuple               # (mean-1σ, mean+1σ) time-averaged
    kv_peak: float
    batch_std_mean: float

    def row(self) -> str:
        return (f"tok={self.tokens_generated} thr={self.throughput:.0f} tok/s "
                f"kv_std={self.kv_std_mean:.0f} band=({self.kv_band[0]:.0f},"
                f"{self.kv_band[1]:.0f}) peak={self.kv_peak:.0f}")


def decode_report(tokens_generated: int, duration: float,
                  kv_timeline: Sequence[Sequence[int]],
                  batch_timeline: Sequence[Sequence[int]]) -> DecodeReport:
    kv_stds = [std(list(map(float, snap))) for snap in kv_timeline if snap]
    kv_means = [mean(list(map(float, snap))) for snap in kv_timeline if snap]
    b_stds = [std(list(map(float, snap))) for snap in batch_timeline if snap]
    kv_peak = max((max(s) for s in kv_timeline if s), default=0)
    m, s = mean(kv_means), mean(kv_stds)
    return DecodeReport(
        tokens_generated=tokens_generated, duration=duration,
        throughput=tokens_generated / duration if duration else float("nan"),
        kv_std_mean=s, kv_band=(m - s, m + s), kv_peak=float(kv_peak),
        batch_std_mean=mean(b_stds),
    )
