"""Discrete-event cluster simulator — reproduces the paper's §5 experiments.

Two drivers:
  PrefillClusterSim — TTFT vs load (Fig 6a/6b), chunk utilization & max QPS
                      (Table 1). Scheduler ∈ {sbs, immediate-rr, immediate-lt}.
  DecodeClusterSim  — KV-load balance (Fig 7) and decode throughput (Fig 8).
                      Scheduler ∈ {sbs (IQR-lex), immediate (rr/least_*)}.

Event loop: a single heap of (time, seq, kind, payload). Engines report
EndForward with measured pass times, closing the Algorithm-1 feedback loop —
the adaptive interval converges online exactly as in §4.1.1.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from repro.config.base import ModelConfig, ServingConfig
from repro.core.prefix_cache import PrefixCacheIndex
from repro.core.scheduler import (
    DecodeScheduler, ImmediatePrefillScheduler, PrefillScheduler,
    StaggeredBatchScheduler,
)
from repro.core.state import GlobalState
from repro.core.interval import AdaptiveIntervalController
from repro.core.types import EndForward, Request
from repro.serving.costmodel import CostModel
from repro.serving.engine import SimDecodeInstance, SimPrefillInstance
from repro.serving.metrics import (
    DecodeReport, PrefillReport, decode_report, prefill_report,
)


class _EventLoop:
    def __init__(self):
        self._heap: List[Tuple[float, int, str, object]] = []
        self._seq = itertools.count()

    def push(self, t: float, kind: str, payload=None):
        heapq.heappush(self._heap, (t, next(self._seq), kind, payload))

    def pop(self):
        return heapq.heappop(self._heap)

    def __bool__(self):
        return bool(self._heap)


def build_state(cfg_s: ServingConfig) -> GlobalState:
    return GlobalState(
        num_prefill_instances=cfg_s.num_prefill_instances,
        prefill_dp_per_instance=cfg_s.prefill_dp_per_instance,
        num_decode_instances=cfg_s.num_decode_instances,
        decode_dp_per_instance=cfg_s.decode_dp_per_instance,
        chunk_size=cfg_s.chunk_size,
        interval=AdaptiveIntervalController(
            window_size=cfg_s.window_size, l_net=cfg_s.l_net,
            t_default=cfg_s.t_default,
            n_active=cfg_s.num_prefill_instances),
        max_batch_per_dp=cfg_s.max_batch_per_dp,
        kv_budget_tokens=cfg_s.kv_budget_tokens,
    )


class PrefillClusterSim:
    def __init__(self, model_cfg: ModelConfig, serving_cfg: ServingConfig,
                 scheduler: str = "sbs", cost: Optional[CostModel] = None):
        self.cfg_s = serving_cfg
        self.cost = cost or CostModel(model_cfg)
        self.state = build_state(serving_cfg)
        if scheduler == "sbs":
            cache = None
            if serving_cfg.cache_aware:
                cache = PrefixCacheIndex(
                    [d.dp_id for d in self.state.prefill_dps])
            self.sched: PrefillScheduler = StaggeredBatchScheduler(
                self.state, n_limit=serving_cfg.n_limit,
                cache_aware=serving_cfg.cache_aware, prefix_cache=cache,
                watchdog_multiplier=serving_cfg.watchdog_multiplier)
        elif scheduler in ("immediate-rr", "immediate-lt"):
            pol = "round_robin" if scheduler.endswith("rr") else "least_tokens"
            self.sched = ImmediatePrefillScheduler(self.state, pol)
        else:
            raise ValueError(scheduler)
        self.instances = [
            SimPrefillInstance(
                i, [d.dp_id for d in self.state.prefill_dps_of(i)],
                serving_cfg.chunk_size, self.cost)
            for i in range(serving_cfg.num_prefill_instances)]
        self._pass_start: Dict[int, float] = {}

    # ------------------------------------------------------------------
    def run(self, requests: Sequence[Request], duration: float
            ) -> PrefillReport:
        ev = _EventLoop()
        for r in requests:
            ev.push(r.arrival_time, "arrival", r)
        now = 0.0
        next_poll: Optional[float] = None
        horizon = duration * 20 + 60.0    # drain guard

        def schedule_poll(t: Optional[float]):
            nonlocal next_poll
            if t is None:
                return
            if next_poll is None or t < next_poll - 1e-12:
                next_poll = t
                ev.push(t, "poll", None)

        while ev:
            now, _, kind, payload = ev.pop()
            if now > horizon:
                break
            if kind == "arrival":
                self.sched.on_arrival(payload, now)
            elif kind == "pass_end":
                inst: SimPrefillInstance = payload
                start = self._pass_start.pop(inst.instance_id)
                res = inst.finish_pass(now)
                for e in res.end_forwards:
                    e.exec_time = now - start
                    self.sched.on_end_forward(e)
            elif kind == "poll":
                if next_poll is not None and abs(now - next_poll) < 1e-9:
                    next_poll = None
            # after any event: poll scheduler, start passes
            for cmd in self.sched.poll(now):
                self.instances[cmd.instance_id].enqueue(cmd, now)
            for inst in self.instances:
                dur = inst.start_pass(now)
                if dur is not None:
                    self._pass_start[inst.instance_id] = now
                    ev.push(now + dur, "pass_end", inst)
            schedule_poll(self.sched.next_event_time(now))

        util = (sum(i.tokens_processed for i in self.instances)
                / max(sum(i.capacity_offered for i in self.instances), 1))
        rejected = len(getattr(self.sched, "rejected", []))
        return prefill_report(requests, duration, util, rejected)


class DecodeClusterSim:
    def __init__(self, model_cfg: ModelConfig, serving_cfg: ServingConfig,
                 scheduler: str = "sbs", policy: str = "round_robin",
                 cost: Optional[CostModel] = None,
                 snapshot_every: int = 1):
        self.cfg_s = serving_cfg
        self.cost = cost or CostModel(model_cfg)
        self.state = build_state(serving_cfg)
        mode = "sbs" if scheduler == "sbs" else "immediate"
        self.sched = DecodeScheduler(
            self.state, mode=mode, policy=policy, iqr_k=serving_cfg.iqr_k,
            window=serving_cfg.l_net * 10 + 0.02)
        self.instances = [
            SimDecodeInstance(
                i, [d.dp_id for d in self.state.decode_dps_of(i)], self.cost)
            for i in range(serving_cfg.num_decode_instances)]
        self._dp2inst = {d.dp_id: d.instance_id for d in self.state.decode_dps}
        self.kv_timeline: List[List[int]] = []
        self.batch_timeline: List[List[int]] = []
        self.snapshot_every = snapshot_every

    def _place(self, placements: Optional[Dict[int, List[Request]]]):
        if not placements:
            return
        for dp_id, reqs in placements.items():
            inst = self.instances[self._dp2inst[dp_id]]
            for r in reqs:
                inst.admit(dp_id, r)

    def run(self, requests: Sequence[Request], duration: float,
            closed_loop: int = 0) -> DecodeReport:
        """Open-loop: requests arrive by their arrival_time. Closed-loop
        (paper §5.2.2: 'average batch size 35'): hold `closed_loop`
        concurrent requests — each finish immediately admits the next."""
        ev = _EventLoop()
        template = list(requests)
        if closed_loop:
            n0 = min(len(template), closed_loop)
            pool = iter(template[n0:])
            for r in template[:n0]:
                r.arrival_time = 0.0
                ev.push(0.0, "arrival", r)
        else:
            pool = iter(())
            for r in template:
                ev.push(r.arrival_time, "arrival", r)
        now, steps = 0.0, 0
        horizon = (duration * 20 + 60.0) if not closed_loop else duration
        while ev:
            now, _, kind, payload = ev.pop()
            if now > horizon:
                break
            if kind == "arrival":
                self._place(self.sched.on_handoff(payload, now))
            elif kind == "step_end":
                inst: SimDecodeInstance = payload
                done = inst.finish_step(now, self.state.decode_dps)
                if closed_loop:
                    for _ in done:
                        nxt = next(pool, None)
                        if nxt is not None:
                            nxt.arrival_time = now
                            ev.push(now, "arrival", nxt)
                steps += 1
                if steps % self.snapshot_every == 0:
                    self.kv_timeline.append(
                        [d.kv_tokens for d in self.state.decode_dps])
                    self.batch_timeline.append(
                        [d.batch for d in self.state.decode_dps])
            elif kind == "window":
                pass
            self._place(self.sched.poll(now))
            for inst in self.instances:
                dur = inst.start_step(self.state.decode_dps)
                if dur is not None:
                    ev.push(now + dur, "step_end", inst)
            nxt = self.sched.next_event_time(now)
            if nxt is not None and nxt > now:
                ev.push(nxt, "window", None)
        total = sum(i.tokens_generated for i in self.instances)
        return decode_report(total, max(now, 1e-9),
                             self.kv_timeline, self.batch_timeline)
