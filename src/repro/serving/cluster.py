"""Cluster simulators — thin configuration wrappers over the unified
`repro.serving.runtime.ClusterRuntime` event loop (paper §5 experiments).

  PrefillClusterSim — TTFT vs load (Fig 6a/6b), chunk utilization & max QPS
                      (Table 1). Scheduler ∈ {sbs, immediate-rr, immediate-lt}.
  DecodeClusterSim  — KV-load balance (Fig 7) and decode throughput (Fig 8).
                      Scheduler ∈ {sbs (IQR-lex), sbs-la (load-aware global
                      allocation), immediate (rr/least_*)}.

Engines report EndForward with measured pass times, closing the
Algorithm-1 feedback loop — the adaptive interval converges online exactly
as in §4.1.1.  The P/D-separated pipeline lives in repro.serving.e2e.
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.config.base import ModelConfig, ServingConfig
from repro.core.prefix_cache import PrefixCacheIndex
from repro.core.scheduler import (
    DecodeScheduler, ImmediatePrefillScheduler, PrefillScheduler,
    StaggeredBatchScheduler,
)
from repro.core.state import GlobalState
from repro.core.interval import AdaptiveIntervalController
from repro.core.types import Request
from repro.serving.costmodel import CostModel
from repro.serving.engine import (
    SimDecodeInstance, SimPrefillInstance, SimUnifiedInstance,
)
from repro.serving.metrics import (
    DecodeReport, PrefillReport, decode_report, prefill_report,
)
from repro.serving.runtime import ClusterRuntime, EventLoop

# back-compat alias (pre-runtime callers imported the private loop)
_EventLoop = EventLoop


def build_state(cfg_s: ServingConfig) -> GlobalState:
    return GlobalState(
        num_prefill_instances=cfg_s.num_prefill_instances,
        prefill_dp_per_instance=cfg_s.prefill_dp_per_instance,
        num_decode_instances=cfg_s.num_decode_instances,
        decode_dp_per_instance=cfg_s.decode_dp_per_instance,
        chunk_size=cfg_s.chunk_size,
        interval=AdaptiveIntervalController(
            window_size=cfg_s.window_size, l_net=cfg_s.l_net,
            t_default=cfg_s.t_default,
            n_active=cfg_s.num_prefill_instances),
        max_batch_per_dp=cfg_s.resolved_decode_slots,
        kv_budget_tokens=cfg_s.kv_budget_tokens,
        block_size=cfg_s.block_size,
    )


def build_prefill_scheduler(state: GlobalState, scfg: ServingConfig,
                            scheduler: str) -> PrefillScheduler:
    if scheduler == "sbs":
        cache = None
        if scfg.cache_aware:
            cache = PrefixCacheIndex(
                [d.dp_id for d in state.prefill_dps],
                block=scfg.block_size or 16)
        return StaggeredBatchScheduler(
            state, n_limit=scfg.n_limit, cache_aware=scfg.cache_aware,
            prefix_cache=cache,
            watchdog_multiplier=scfg.watchdog_multiplier,
            bucket_size=scfg.bucket_size,
            bucket_max_wait=scfg.bucket_max_wait)
    if scheduler in ("immediate-rr", "immediate-lt"):
        pol = "round_robin" if scheduler.endswith("rr") else "least_tokens"
        return ImmediatePrefillScheduler(state, pol)
    raise ValueError(scheduler)


def build_decode_scheduler(state: GlobalState, scfg: ServingConfig,
                           scheduler: str, policy: str = "round_robin",
                           watchdog_multiplier: float = 0.0,
                           cache_aware: Optional[bool] = None
                           ) -> DecodeScheduler:
    """Decode plane scheduler for any driver (sim or real):
    'sbs' = IQR-lex batched placement, 'sbs-la' = Load-Aware Global
    Allocation, 'immediate' = per-handoff placement baseline.

    With `scfg.cache_aware` (overridable via the `cache_aware` arg, which
    the real server sets when prefix caching is on), 'sbs-la' and
    'immediate' get cache-aware placement: a per-decode-DP prefix index
    steers each hand-off to the DP already holding the longest prefix of
    its prompt (the real plane's per-DP page binders then resolve that
    prefix to live pages)."""
    if scheduler not in ("sbs", "sbs-la", "immediate"):
        raise ValueError(scheduler)
    mode = "immediate" if scheduler == "immediate" else "sbs"
    alloc = "load_aware" if scheduler == "sbs-la" else "lex"
    if cache_aware is None:
        cache_aware = scfg.cache_aware
    cache = None
    if cache_aware and scheduler in ("sbs-la", "immediate"):
        cache = PrefixCacheIndex(
            [d.dp_id for d in state.decode_dps],
            block=scfg.block_size or 16)
    return DecodeScheduler(
        state, mode=mode, policy=policy, iqr_k=scfg.iqr_k,
        window=scfg.l_net * 10 + 0.02, alloc=alloc,
        watchdog_multiplier=watchdog_multiplier,
        prefix_cache=cache, bucket_size=scfg.bucket_size)


def build_prefill_instances(state: GlobalState, scfg: ServingConfig,
                            cost: CostModel):
    return [SimPrefillInstance(
                i, [d.dp_id for d in state.prefill_dps_of(i)],
                scfg.chunk_size, cost)
            for i in range(scfg.num_prefill_instances)]


def build_decode_instances(state: GlobalState, scfg: ServingConfig,
                           cost: CostModel, unified: Optional[bool] = None):
    """`unified` (default: scfg.mixed_batch) swaps in the mixed-batch
    plane: SimUnifiedInstance runs chunked prefill piggybacked on the
    decode steps, so the deployment needs no prefill pool at all."""
    if unified is None:
        unified = scfg.mixed_batch
    if unified:
        return [SimUnifiedInstance(
                    i, [d.dp_id for d in state.decode_dps_of(i)], cost,
                    chunk=scfg.resolved_mixed_chunk,
                    starve_limit=scfg.prefill_starve_limit,
                    piggyback=scfg.mixed_piggyback)
                for i in range(scfg.num_decode_instances)]
    return [SimDecodeInstance(
                i, [d.dp_id for d in state.decode_dps_of(i)], cost)
            for i in range(scfg.num_decode_instances)]


class PrefillClusterSim:
    """Prefill-only pool: one plane of the unified runtime."""

    def __init__(self, model_cfg: ModelConfig, serving_cfg: ServingConfig,
                 scheduler: str = "sbs", cost: Optional[CostModel] = None):
        self.cfg_s = serving_cfg
        self.cost = cost or CostModel(model_cfg)
        self.state = build_state(serving_cfg)
        self.sched = build_prefill_scheduler(self.state, serving_cfg,
                                             scheduler)
        self.instances = build_prefill_instances(self.state, serving_cfg,
                                                 self.cost)
        self.runtime = ClusterRuntime(
            self.state, prefill_sched=self.sched,
            prefill_instances=self.instances)

    def run(self, requests: Sequence[Request], duration: float
            ) -> PrefillReport:
        self.runtime.run(requests, duration,
                         horizon=duration * 20 + 60.0)   # drain guard
        rejected = len(getattr(self.sched, "rejected", []))
        return prefill_report(requests, duration, self.runtime.prefill_util,
                              rejected)


class DecodeClusterSim:
    """Decode-only pool: arrivals are hand-offs straight into the decode
    scheduler.  scheduler='sbs-la' selects the load-aware global
    allocator; `watchdog_multiplier` > 0 arms the re-dispatch path."""

    def __init__(self, model_cfg: ModelConfig, serving_cfg: ServingConfig,
                 scheduler: str = "sbs", policy: str = "round_robin",
                 cost: Optional[CostModel] = None,
                 snapshot_every: int = 1,
                 watchdog_multiplier: float = 0.0):
        self.cfg_s = serving_cfg
        self.cost = cost or CostModel(model_cfg)
        self.state = build_state(serving_cfg)
        self.sched = build_decode_scheduler(
            self.state, serving_cfg, scheduler, policy=policy,
            watchdog_multiplier=watchdog_multiplier)
        self.instances = build_decode_instances(self.state, serving_cfg,
                                                self.cost)
        self.runtime = ClusterRuntime(
            self.state, decode_sched=self.sched,
            decode_instances=self.instances, snapshot_every=snapshot_every)

    @property
    def kv_timeline(self):
        return self.runtime.kv_timeline

    @property
    def batch_timeline(self):
        return self.runtime.batch_timeline

    def run(self, requests: Sequence[Request], duration: float,
            closed_loop: int = 0) -> DecodeReport:
        """Open-loop: requests arrive by their arrival_time. Closed-loop
        (paper §5.2.2: 'average batch size 35'): hold `closed_loop`
        concurrent requests — each finish immediately admits the next."""
        horizon = (duration * 20 + 60.0) if not closed_loop else duration
        end = self.runtime.run(requests, duration, horizon=horizon,
                               closed_loop=closed_loop)
        return decode_report(self.runtime.tokens_generated, max(end, 1e-9),
                             self.runtime.kv_timeline,
                             self.runtime.batch_timeline)
