"""Block-granular prefix-cache reuse: RadixTree nodes bound to BlockPool
pages (vLLM block-manager design, SGLang-style radix resolution).

`PagePrefixBinder` is the glue object the real engines own, one per
physical `BlockPool` (per prefill engine on the prefill plane, per
decode DP on the decode plane):

  * `claim(tokens)`  resolves the longest cached prefix of a prompt to
    live physical block ids and takes one pool reference per block for
    the caller — the caller's block table then POINTS AT the cached
    pages instead of recomputing them.  An exact full-prompt hit also
    returns the stored first output token, so prefill can be skipped
    entirely (zero chunks).
  * `insert(tokens, block_ids, first_token)` publishes a finished
    prompt's pages into the tree.  The tree holds one reference per
    bound node, so LRU eviction is a DECREF — a page shared with a live
    block table survives eviction and is reclaimed only when its last
    holder lets go ("LRU eviction only frees refcount-0 blocks").
  * `ensure_free(n)` is pool-pressure eviction: peel LRU entries until
    `n` blocks are free, bounded by the cache emptying.

Sharing is strictly BLOCK-granular and position-exact: a partial tail
block is bound only together with a `first_token` payload (it is usable
only by an exact-length repeat of the same prompt, which never writes
into it during prefill; a decode-side adopter write triggers
copy-on-write).  Content keys published to `BlockPool.bind` are the
exact token prefix through the block, so the content-addressed map can
never alias two different prefixes.

`EngineBackedPrefixIndex` adapts a set of binders to the
`PrefixCacheIndex` shape `prefill_alloc.greedy_dispatch` consumes, so
cache-aware PBAA credits exactly the chunks the real engine will skip.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.prefix_cache import RadixTree
from repro.serving.kv_pool import BlockPool


class PagePrefixBinder:
    """Radix prefix index over one `BlockPool`'s physical pages."""

    def __init__(self, pool: BlockPool, budget_tokens: Optional[int] = None,
                 block_size: Optional[int] = None):
        self.pool = pool
        self.block = block_size or pool.block_size
        budget = (budget_tokens if budget_tokens is not None
                  else pool.capacity_tokens)
        self.tree = RadixTree(budget, self.block, on_evict=self._on_evict)
        # reuse accounting the benchmark harness reads (engine truth, vs
        # the scheduler-side PrefixCacheIndex estimate)
        self.hit_tokens = 0
        self.seen_tokens = 0

    def _on_evict(self, node) -> None:
        # decref, not force-free: pages shared with live block tables
        # survive their cache entry
        if node.blocks:
            self.pool.free(node.blocks)

    # -- resolution ------------------------------------------------------
    def _usable(self, tokens: Sequence[int]) -> Tuple[int, List]:
        """Walk the tree, stopping at the first node without a page
        binding; returns (usable tokens, bound node path)."""
        matched, path = self.tree.match_path(tokens)
        nodes, usable = [], 0
        for n in path:
            if not n.blocks:
                break
            nodes.append(n)
            usable += n.tokens
        return min(usable, matched), nodes

    def peek(self, tokens: Sequence[int]) -> Tuple[int, bool]:
        """(claimable prefix tokens, exact-full-hit?) without taking any
        references — the scheduler-side view of `claim`."""
        if not tokens:
            return 0, False
        usable, nodes = self._usable(tokens)
        if (usable >= len(tokens) and nodes
                and nodes[-1].value is not None):
            return len(tokens), True
        claim = min(usable, max(len(tokens) - 1, 0))
        return (claim // self.block) * self.block, False

    def claim(self, tokens: Sequence[int]
              ) -> Tuple[int, List[int], Optional[int]]:
        """Resolve the longest cached prefix to physical pages, taking
        one pool reference per returned block for the caller.

        Returns (claimed tokens, block ids, first_token-or-None).  A
        full hit claims the whole prompt including the partial tail
        block and carries the stored first output token; otherwise the
        claim is capped at len-1 (the last position's logits must be
        computed) and floored to block granularity.
        """
        if not tokens:
            return 0, [], None
        usable, nodes = self._usable(tokens)
        if (usable >= len(tokens) and nodes
                and nodes[-1].value is not None):
            blocks = [b for n in nodes for b in n.blocks]
            self.pool.incref(blocks)
            return len(tokens), blocks, nodes[-1].value
        claim = min(usable, max(len(tokens) - 1, 0))
        claim = (claim // self.block) * self.block
        nb = claim // self.block
        # non-terminal edges are exactly `block` tokens / one page each
        blocks = [b for n in nodes[:nb] for b in n.blocks]
        self.pool.incref(blocks)
        return claim, blocks, None

    # -- publication -----------------------------------------------------
    def insert(self, tokens: Sequence[int], block_ids: Sequence[int],
               first_token: Optional[int] = None) -> None:
        """Publish a finished prompt's pages.  `block_ids` holds one id
        per block-sized slice of `tokens` (the request's block table
        prefix).  The tree takes one reference per NEWLY bound node
        (first copy wins — later identical prompts share the first
        pages); the partial tail block is bound only when a
        `first_token` payload makes it usable (exact-sequence hit)."""
        toks = tuple(tokens)
        n_full = len(toks) // self.block
        if first_token is None:
            toks = toks[: n_full * self.block]
            block_ids = list(block_ids)[:n_full]
        if not toks:
            return
        edges = [toks[i:i + self.block]
                 for i in range(0, len(toks), self.block)]
        if len(block_ids) < len(edges):
            raise ValueError(
                f"{len(block_ids)} blocks cannot bind {len(edges)} edges")
        # which edges will this insert NEWLY bind?  (the tree keeps the
        # first binding, so only those gain a tree-held reference)
        newly: List[int] = []
        node = self.tree.root
        for i, blk in enumerate(edges):
            nxt = node.edges.get(blk)
            if nxt is None:
                newly.extend(block_ids[i:len(edges)])
                break
            if not nxt.blocks:
                newly.append(block_ids[i])
            node = nxt
        if newly:
            self.pool.incref(newly)
        self.tree.insert(toks, blocks=[(b,) for b in block_ids[:len(edges)]],
                         value=first_token)
        # content-addressed page map: key = the exact prefix through the
        # block, so lookups can never alias distinct prefixes
        for i in range(len(edges)):
            self.pool.bind(toks[: (i + 1) * self.block], block_ids[i])

    # -- pool pressure ---------------------------------------------------
    def ensure_free(self, need_blocks: int) -> bool:
        """Evict LRU cache entries until `need_blocks` pool blocks are
        free (or the cache is empty).  Eviction decrefs, so shared pages
        are unpinned from the CACHE without yanking them from live block
        tables."""
        while self.pool.free_count < need_blocks:
            if self.tree.evict_tokens(1) == 0:
                break
        return self.pool.free_count >= need_blocks

    def record(self, hit: int, prompt: int) -> None:
        self.hit_tokens += hit
        self.seen_tokens += prompt

    @property
    def hit_rate(self) -> float:
        return self.hit_tokens / self.seen_tokens if self.seen_tokens else 0.0


class EngineBackedPrefixIndex:
    """`PrefixCacheIndex`-shaped view over the prefill engines' REAL page
    binders, for cache-aware PBAA on the real plane.

    `match` asks the dp's binder what a claim would return, so the
    scheduler credits exactly the chunks the engine skips (poll →
    enqueue is synchronous on the runtime thread — no engine state can
    change between the credit and the claim).  `insert` is a no-op:
    pages are published by the ENGINE at prefill completion, not
    speculatively by the scheduler.  `first_dispatch_only` tells
    `greedy_dispatch` not to re-credit later chunks of an already
    claimed (pinned) request."""

    first_dispatch_only = True

    def __init__(self, binder_of: Dict[int, PagePrefixBinder]):
        self._binder_of = dict(binder_of)       # dp_id -> engine binder
        self.hit_tokens = 0
        self.seen_tokens = 0

    def match(self, dp_id: int, tokens: Optional[Sequence[int]],
              limit: Optional[int] = None) -> int:
        binder = self._binder_of.get(dp_id)
        if binder is None or tokens is None:
            return 0
        claim, _full = binder.peek(tokens)
        return min(claim, limit) if limit is not None else claim

    def insert(self, dp_id: int, tokens: Optional[Sequence[int]]) -> int:
        return 0

    def record(self, hit: int, prompt: int) -> None:
        self.hit_tokens += hit
        self.seen_tokens += prompt

    @property
    def hit_rate(self) -> float:
        return self.hit_tokens / self.seen_tokens if self.seen_tokens else 0.0
