"""Real-execution SBS server: the scheduler drives ACTUAL JAX model forwards.

This is the end-to-end integration path (used by examples/serve_e2e.py and
the integration tests): engine threads execute true chunked prefill
(`prefill_chunk`) and decode (`decode_step`) on a real model, report
EndForward signals with measured wall-times, and the Algorithm-1 feedback
loop adapts the dispatch interval online. Wall-clock here is CPU time on a
tiny model — the control plane is identical to the production layout.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig, ServingConfig
from repro.core.scheduler import StaggeredBatchScheduler, ImmediatePrefillScheduler
from repro.core.state import GlobalState
from repro.core.interval import AdaptiveIntervalController
from repro.core.types import DispatchCommand, EndForward, Request, RequestPhase
from repro.models import decode_step, init_cache, prefill
from repro.models.model import prefill_chunk
from repro.serving.cluster import build_state


@dataclasses.dataclass
class Generation:
    rid: int
    tokens: List[int]
    ttft: float
    finish: float


class _ReqCtx:
    def __init__(self, req: Request):
        self.req = req
        self.cache = None
        self.consumed = 0
        self.generated: List[int] = []
        self.done = threading.Event()


class RealInstanceEngine(threading.Thread):
    """One inference instance: executes dispatched chunks per DP unit
    (serially on CPU — DP parallelism is simulated by the sync-barrier cost
    already being the max over DPs on real hardware)."""

    def __init__(self, instance_id: int, cfg: ModelConfig, params,
                 feedback: "queue.Queue[EndForward]", max_len: int = 256,
                 max_new: int = 16):
        super().__init__(daemon=True)
        self.instance_id = instance_id
        self.cfg = cfg
        self.params = params
        self.feedback = feedback
        self.inbox: "queue.Queue[Optional[DispatchCommand]]" = queue.Queue()
        self.max_len = max_len
        self.max_new = max_new
        self.ctx: Dict[int, _ReqCtx] = {}
        self.results: Dict[int, Generation] = {}
        self._chunk = jax.jit(
            lambda p, t, c: prefill_chunk(cfg, p, t, c))
        self._decode = jax.jit(
            lambda p, t, c: decode_step(cfg, p, t, c))

    def submit(self, cmd: DispatchCommand) -> None:
        self.inbox.put(cmd)

    def stop(self) -> None:
        self.inbox.put(None)

    def run(self) -> None:
        while True:
            cmd = self.inbox.get()
            if cmd is None:
                return
            t0 = time.monotonic()
            processed: Dict[int, int] = {}
            for dp_id, lst in cmd.assignments.items():
                ptok = 0
                for req, tok in lst:
                    self._process_chunk(req, tok)
                    ptok += tok
                processed[dp_id] = ptok
            dur = time.monotonic() - t0
            now = time.monotonic()
            for dp_id, ptok in processed.items():
                self.feedback.put(EndForward(
                    instance_id=self.instance_id, dp_id=dp_id,
                    exec_time=dur, processed_tokens=ptok,
                    remaining_tokens=0, timestamp=now))

    # ------------------------------------------------------------------
    def _process_chunk(self, req: Request, tok: int) -> None:
        ctx = self.ctx.get(req.rid)
        if ctx is None:
            ctx = self.ctx[req.rid] = _ReqCtx(req)
            ctx.cache = init_cache(self.cfg, 1, self.max_len)
        ids = req.tokens[ctx.consumed: ctx.consumed + tok]
        if not ids:
            return
        arr = jnp.asarray([ids], jnp.int32)
        logits, ctx.cache = self._chunk(self.params, arr, ctx.cache)
        ctx.consumed += tok
        if ctx.consumed >= req.input_len:
            # prefill complete: emit first token, then decode to completion
            if req.prefill_start is None:
                req.prefill_start = time.monotonic()
            nxt = int(jnp.argmax(logits[0]))
            ctx.generated.append(nxt)
            req.first_token_time = time.monotonic()
            n_new = min(req.output_len, self.max_new)
            for _ in range(n_new - 1):
                lg, ctx.cache = self._decode(
                    self.params, jnp.asarray([[nxt]], jnp.int32), ctx.cache)
                nxt = int(jnp.argmax(lg[0]))
                ctx.generated.append(nxt)
            req.finish_time = time.monotonic()
            req.phase = RequestPhase.FINISHED
            self.results[req.rid] = Generation(
                rid=req.rid, tokens=list(ctx.generated),
                ttft=req.first_token_time - req.arrival_time,
                finish=req.finish_time)
            ctx.done.set()


class RealSBSServer:
    """SBS control plane over real engines."""

    def __init__(self, cfg: ModelConfig, params,
                 serving_cfg: Optional[ServingConfig] = None,
                 scheduler: str = "sbs", max_len: int = 256,
                 max_new: int = 8):
        self.cfg = cfg
        scfg = serving_cfg or ServingConfig(
            num_prefill_instances=2, prefill_dp_per_instance=2,
            chunk_size=32, t_default=0.05, l_net=0.001)
        self.scfg = scfg
        self.state = build_state(scfg)
        if scheduler == "sbs":
            self.sched = StaggeredBatchScheduler(self.state,
                                                 n_limit=scfg.n_limit)
        else:
            self.sched = ImmediatePrefillScheduler(self.state)
        self.feedback: "queue.Queue[EndForward]" = queue.Queue()
        self.engines = [
            RealInstanceEngine(i, cfg, params, self.feedback,
                               max_len=max_len, max_new=max_new)
            for i in range(scfg.num_prefill_instances)]

    def serve(self, requests: Sequence[Request], timeout: float = 120.0
              ) -> List[Generation]:
        for e in self.engines:
            e.start()
        t_start = time.monotonic()
        reqs = sorted(requests, key=lambda r: r.arrival_time)
        pending = list(reqs)
        deadline = t_start + timeout
        try:
            while time.monotonic() < deadline:
                now = time.monotonic()
                rel = now - t_start
                # admit arrivals whose time has come
                while pending and pending[0].arrival_time <= rel:
                    r = pending.pop(0)
                    r.arrival_time = t_start + r.arrival_time  # absolute
                    self.sched.on_arrival(r, now)
                # feedback fast path
                try:
                    while True:
                        ev = self.feedback.get_nowait()
                        self.sched.on_end_forward(ev)
                except queue.Empty:
                    pass
                for cmd in self.sched.poll(now):
                    self.engines[cmd.instance_id].submit(cmd)
                done = sum(len(e.results) for e in self.engines)
                if done == len(reqs):
                    break
                time.sleep(0.002)
        finally:
            for e in self.engines:
                e.stop()
            for e in self.engines:
                e.join(timeout=10)
        out: List[Generation] = []
        for e in self.engines:
            out.extend(e.results.values())
        return sorted(out, key=lambda g: g.rid)
