"""Real-execution SBS server: ClusterRuntime driving ACTUAL JAX forwards.

This is the end-to-end integration path (used by examples/serve_e2e.py and
the integration tests).  Since the EnginePlane refactor it is a thin
deployment wrapper: the SAME `ClusterRuntime` event loop that drives the
cost-model simulators runs here in realtime (wall-clock) mode over
`RealPrefillEngine` / `RealDecodeEngine` threads — a P/D-separated
deployment with true chunked prefill, an explicit KV-cache handoff
between the pools, and continuous batched decode.  Every scheduler
variant of the simulators (`immediate`, `sbs`, `sbs-la`) runs unchanged
over the real plane, with EndForward signals carrying measured wall
times so the Algorithm-1 feedback loop adapts the dispatch interval
online.  Wall-clock here is CPU time on a tiny model — the control plane
is identical to the production layout.

The server never rewrites caller-owned `arrival_time` (the runtime
clock is relative wall time), so the same WORKLOAD can be replayed
across serve() calls — but build fresh Request objects per call:
progress fields (remaining_prefill, generated, phase, finish stamps)
are mutated in place by a run, and re-submitting finished objects would
re-enter the pipeline mid-state.  Repeated serve() is supported after a
COMPLETED run: each call spawns fresh worker threads and the runtime
resets time-gated scheduler stamps to the new clock; the adapted
T_fwd/interval estimate deliberately persists (warm start).  After a
timeout the deployment may still hold in-flight passes and should be
discarded.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro.config.base import ModelConfig, ServingConfig
from repro.core.flow_control import FlowController
from repro.core.types import Request
from repro.serving.cluster import (
    build_decode_scheduler, build_prefill_scheduler, build_state,
)
from repro.serving.page_share import EngineBackedPrefixIndex
from repro.serving.real_engine import (
    EngineSpec, KVHandoffBus, RealDecodeEngine, RealPrefillEngine,
    RealUnifiedEngine,
)
from repro.serving.runtime import ClusterRuntime


@dataclasses.dataclass
class Generation:
    rid: int
    tokens: List[int]
    ttft: float
    finish: float


def _default_serving_config() -> ServingConfig:
    return ServingConfig(
        num_prefill_instances=2, prefill_dp_per_instance=2,
        num_decode_instances=1, decode_dp_per_instance=2,
        chunk_size=32, t_default=0.05, l_net=0.001,
        max_batch_per_dp=8)


class RealSBSServer:
    """SBS control plane over real engines.

    scheduler ∈ {sbs, sbs-la, immediate}: identical meaning to
    `PDClusterSim` — 'sbs-la' keeps SBS prefill dispatch and switches the
    decode pool to Load-Aware Global Allocation; 'immediate' is the
    baseline on both phases."""

    def __init__(self, cfg: ModelConfig, params,
                 serving_cfg: Optional[ServingConfig] = None,
                 scheduler: str = "sbs", max_len: int = 256,
                 max_new: int = 8,
                 watchdog_multiplier: float = 0.0,
                 spec: Optional[EngineSpec] = None,
                 prefix_cache: bool = False,
                 mesh=None):
        self.cfg = cfg
        scfg = serving_cfg or _default_serving_config()
        self.scfg = scfg
        self.state = build_state(scfg)
        if scheduler not in ("sbs", "sbs-la", "immediate"):
            raise ValueError(scheduler)
        if scfg.mixed_batch:
            # unified mixed-batch plane: decode-pool-only deployment —
            # no prefill engines, no KV handoff; RealUnifiedEngine runs
            # chunked prefill inside its own (paged) decode steps
            self.sched = None
        elif scheduler == "immediate":
            self.sched = build_prefill_scheduler(self.state, scfg,
                                                 "immediate-rr")
        else:
            self.sched = build_prefill_scheduler(self.state, scfg, "sbs")
        self.dsched = build_decode_scheduler(
            self.state, scfg, scheduler,
            watchdog_multiplier=watchdog_multiplier,
            cache_aware=True if prefix_cache else None)
        # a spec may be shared across server instances (e.g. one per
        # scheduler variant over the same model) so each jitted shape
        # compiles once per process instead of once per server.  With
        # scfg.block_size > 0 the decode plane is PAGED: same KV memory
        # budget (max_batch_per_dp × max_len tokens per DP), block-pool
        # admission, resolved_decode_slots batch rows.
        # `mesh` turns the deployment SHARDED (paged only): the spec's
        # step jits become cross-device mesh programs with the EP
        # all-to-all active, and each decode instance merges its DP
        # units' rows into one data-axis-sharded cache — so the mesh's
        # data size must equal decode_dp_per_instance
        self.spec = spec or EngineSpec(
            cfg, params, max_len=max_len,
            max_batch=scfg.max_batch_per_dp, max_new=max_new,
            block_size=scfg.block_size,
            decode_slots=(scfg.resolved_decode_slots
                          if scfg.block_size else 0),
            mesh=mesh)
        # prefix_cache turns on block-granular prefix reuse end to end:
        # page-native prefill engines with shared refcounted pages (a
        # cached prefix's chunks are never computed), PageHandoff
        # transfers, per-decode-DP binders with eager COW, and cache-
        # aware placement on BOTH schedulers.  Prefill-side claiming
        # needs the credit-granting PBAA path, so the `immediate`
        # baseline shares pages only on the decode side.
        self.prefix_cache = bool(prefix_cache)
        if self.prefix_cache and not self.spec.prefix_sharable:
            raise ValueError(
                "prefix_cache=True needs a paged deployment "
                "(ServingConfig.block_size > 0) and an attention-only "
                "decoder-only model config")
        if scfg.mixed_batch and not self.spec.paged:
            raise ValueError(
                "mixed_batch=True needs a paged deployment "
                "(ServingConfig.block_size > 0)")
        share_prefill = (self.prefix_cache and not scfg.mixed_batch
                         and scheduler in ("sbs", "sbs-la"))
        self.bus = KVHandoffBus()
        self.engines = [] if scfg.mixed_batch else [
            RealPrefillEngine(
                i, [d.dp_id for d in self.state.prefill_dps_of(i)],
                scfg.chunk_size, self.spec, self.bus,
                page_native=self.prefix_cache,
                share_prefix=share_prefill)
            for i in range(scfg.num_prefill_instances)]
        if share_prefill:
            # cache-aware PBAA must credit EXACTLY what the engines will
            # claim: swap the scheduler's simulated index for a view over
            # the real page binders (insert is engine-owned, a no-op here)
            binder_of = {}
            for i, eng in enumerate(self.engines):
                for d in self.state.prefill_dps_of(i):
                    binder_of[d.dp_id] = eng.binder
            self.sched.cache = EngineBackedPrefixIndex(binder_of)
        if scfg.mixed_batch:
            self.decode_engines = [
                RealUnifiedEngine(
                    i, [d.dp_id for d in self.state.decode_dps_of(i)],
                    self.spec, self.bus,
                    chunk=scfg.resolved_mixed_chunk,
                    starve_limit=scfg.prefill_starve_limit,
                    piggyback=scfg.mixed_piggyback,
                    share_prefix=self.prefix_cache)
                for i in range(scfg.num_decode_instances)]
        else:
            self.decode_engines = [
                RealDecodeEngine(
                    i, [d.dp_id for d in self.state.decode_dps_of(i)],
                    self.spec, self.bus, share_prefix=self.prefix_cache)
                for i in range(scfg.num_decode_instances)]
        flow = (FlowController(n_limit=scfg.n_limit,
                               backoff_base=scfg.flow_backoff)
                if scfg.flow_control else None)
        self.runtime = ClusterRuntime(
            self.state, prefill_sched=self.sched,
            prefill_instances=self.engines or None,
            decode_sched=self.dsched, decode_instances=self.decode_engines,
            transfer_time=(None if scfg.mixed_batch
                           else lambda r: scfg.l_net),  # P/D transfer
            realtime=True,
            flow=flow, preemption=scfg.preemption)

    def serve(self, requests: Sequence[Request], timeout: float = 120.0
              ) -> List[Generation]:
        for r in requests:
            if r.tokens is None or len(r.tokens) < r.input_len:
                raise ValueError(
                    f"request {r.rid}: the real plane needs `tokens` of "
                    f"length >= input_len")
            # every KV entry the request will ever write must fit max_len:
            # beyond it the padded cache would silently drop positions
            # (jitted scatter clamps) and decode garbage
            need = self.spec.lifetime_tokens(r)
            if need > self.spec.max_len:
                raise ValueError(
                    f"request {r.rid}: input_len + generated tokens "
                    f"({need}) exceed max_len={self.spec.max_len}")
        workers = [*self.engines, *self.decode_engines]
        for e in workers:
            e.start()
        try:
            self.runtime.run(requests, duration=timeout, horizon=timeout)
        finally:
            for e in workers:
                e.stop()
            for e in workers:
                e.join_worker(timeout=10)
        out: List[Generation] = []
        for r in requests:
            gen = self.bus.get(r.rid)
            if gen is None or r.finish_time is None:
                continue        # unfinished within the timeout
            out.append(Generation(
                rid=r.rid, tokens=list(gen.tokens),
                ttft=r.ttft if r.ttft is not None else float("nan"),
                finish=r.finish_time))
        return sorted(out, key=lambda g: g.rid)

    def prefix_stats(self) -> dict:
        """Engine-truth reuse counters (all zero when prefix_cache=False):
        prefill hit tokens/rate and skipped full prompts, decode pages
        shared at join and eager COW copies."""
        hit = sum(e.binder.hit_tokens for e in self.engines
                  if e.binder is not None)
        seen = sum(e.binder.seen_tokens for e in self.engines
                   if e.binder is not None)
        return {
            "prefix_hit_tokens": hit,
            "prefix_seen_tokens": seen,
            "prefix_hit_rate": hit / seen if seen else 0.0,
            "prefill_full_hits": sum(e.full_hits for e in self.engines),
            "prefill_chunks_run": sum(e.chunks_run for e in self.engines),
            "decode_blocks_shared": sum(e.blocks_shared
                                        for e in self.decode_engines),
            "decode_cow_copies": sum(e.cow_copies
                                     for e in self.decode_engines),
        }
