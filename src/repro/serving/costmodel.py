"""Roofline-derived engine cost model (hardware adaptation, DESIGN.md §3).

The paper measures wall-clock on H800s; this container has no accelerator,
so the discrete-event simulator prices every forward pass with the same
three-term roofline used in §Roofline of EXPERIMENTS.md, instantiated for
the TPU v5e target:

    peak 197 TFLOP/s bf16 / chip,  819 GB/s HBM / chip,  ~50 GB/s/link ICI.

Prefill pass time  = max(FLOPs/(chips·peak·eff), bytes/(chips·bw)) + t_sync
Decode step time   = max(compute, weights+KV bytes / bw) + t_sync

The DP+EP synchronization barrier (§3.3) appears as max() over per-DP times
at the instance level — stragglers stall the whole instance.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.config.base import ModelConfig

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


@dataclasses.dataclass
class CostModel:
    cfg: ModelConfig
    chips_per_prefill_dp: int = 4     # paper: prefill TP=4 per DP unit
    chips_per_decode_dp: int = 1      # paper: decode TP=1
    decode_ep_size: int = 32          # expert weights sharded over EP group
    mfu: float = 0.55                 # achievable fraction of peak (prefill)
    mbu: float = 0.75                 # achievable fraction of HBM bw (decode)
    t_sync: float = 0.004             # per-pass sync/all-to-all overhead (s)
    avg_ctx: int = 2048               # mean context for attention flops
    a2a_bytes_per_token: Optional[int] = None  # MoE dispatch+combine bytes
    kv_bytes_per_token: Optional[int] = None
    param_bytes: Optional[float] = None
    active_param_bytes: Optional[float] = None

    def __post_init__(self):
        pc = self.cfg.param_counts()
        if self.param_bytes is None:
            self.param_bytes = pc["total"] * 2.0           # bf16
        if self.active_param_bytes is None:
            self.active_param_bytes = pc["active"] * 2.0
        if self.kv_bytes_per_token is None:
            self.kv_bytes_per_token = self._kv_bytes_per_token()
        if self.a2a_bytes_per_token is None:
            self.a2a_bytes_per_token = self._a2a_bytes_per_token()
        self._active_params = pc["active"]

    def with_measured_sync(self, t_sync: float) -> "CostModel":
        """Replace the hardcoded per-pass sync constant with a MEASURED
        per-step collective time (sharded-step wall time minus the
        equivalent single-device step — see `examples/serve_e2e.py
        --sharded-bench` and the `micro/ep_a2a_*` probes in
        `benchmarks/microbench.py`), so simulator sweeps price the DP
        barrier at what the mesh actually charges."""
        return dataclasses.replace(self, t_sync=max(float(t_sync), 0.0))

    def _kv_bytes_per_token(self) -> int:
        from repro.config.base import AttentionKind, LayerKind
        total = 0
        for i in range(self.cfg.num_layers):
            kind = self.cfg.layer_kind(i)
            if kind.name in ("DENSE", "MOE"):
                if self.cfg.attention == AttentionKind.MLA:
                    total += (self.cfg.mla.kv_lora_rank
                              + self.cfg.mla.qk_rope_head_dim) * 2
                else:
                    total += (2 * self.cfg.num_kv_heads
                              * self.cfg.resolved_head_dim) * 2
            # SSM layers: constant state, not per-token — excluded
        return total

    def _a2a_bytes_per_token(self) -> int:
        """All-to-all dispatch+combine activation bytes per token per step —
        the reason batch-size imbalance hurts (§4.3.1 'communication
        inefficiencies')."""
        if not self.cfg.moe.num_experts:
            return 0
        n_moe = sum(1 for i in range(self.cfg.num_layers)
                    if self.cfg.layer_kind(i).name in ("MOE", "SSM_MOE"))
        k = self.cfg.moe.top_k
        return n_moe * 2 * k * self.cfg.d_model * 2   # dispatch + combine, bf16

    # ------------------------------------------------------------------
    def prefill_flops(self, tokens: int, ctx: Optional[int] = None) -> float:
        """FLOPs to prefill `tokens` prompt tokens at mean context `ctx`.
        Also the unit in which prefix-cache savings are priced: a cached
        prefix of T tokens skips exactly prefill_flops(T)."""
        if tokens <= 0:
            return 0.0
        ctx = ctx or self.avg_ctx
        flops = 2.0 * self._active_params * tokens
        # attention ~ 2·2·L·d_head·H·ctx per token (rough quadratic term)
        flops += 4.0 * self.cfg.num_layers * self.cfg.d_model * ctx * tokens
        return flops

    def prefill_dp_time(self, tokens: int, ctx: Optional[int] = None) -> float:
        """One DP unit processing `tokens` prompt tokens."""
        if tokens <= 0:
            return 0.0
        flops = self.prefill_flops(tokens, ctx)
        chips = self.chips_per_prefill_dp
        t_comp = flops / (chips * PEAK_FLOPS * self.mfu)
        t_mem = (self.active_param_bytes / 8.0) / (chips * HBM_BW * self.mbu)
        return max(t_comp, t_mem)

    min_fill: float = 0.5             # §3.2 "batch-insensitive latency":
                                      # partial passes cost at least this
                                      # fraction of a full-chunk pass

    def prefill_pass_time(self, dp_tokens: Sequence[int],
                          chunk: Optional[int] = None) -> float:
        """Instance-level pass: sync barrier => max over DP units + overhead.

        Paper §3.2 'Batch-Insensitive Latency': within capacity limits a
        pass's execution time is dominated by the longest sequence and
        synchronization overhead rather than the token count — modeled as a
        floor of `min_fill`·chunk tokens on the pass cost."""
        if not dp_tokens or max(dp_tokens) <= 0:
            return self.t_sync
        load = max(dp_tokens)
        if chunk is not None:
            load = max(load, int(chunk * self.min_fill))
        return self.prefill_dp_time(load) + self.t_sync

    # ------------------------------------------------------------------
    def decode_dp_time(self, batch: int, kv_tokens: int) -> float:
        """One decode iteration on one DP unit (memory-bound).

        `kv_tokens` is the KV footprint actually swept from HBM each
        step.  Callers pass `DecodeDPState.kv_occupancy`: exact resident
        tokens on a padded deployment, reserved-block tokens (internal
        fragmentation included) on a paged one — so the sim plane prices
        the same block-granular reads the real paged engine performs."""
        if batch <= 0:
            return 0.0
        chips = self.chips_per_decode_dp
        flops = 2.0 * self._active_params * batch / self.decode_ep_size
        t_comp = flops / (chips * PEAK_FLOPS * self.mfu)
        # per-chip traffic: weights are sharded over the EP group (each rank
        # reads its expert shard once per iteration); the DP unit's own KV
        # cache is read in full every step — the K_i term of Algorithm 3.
        bytes_moved = (self.active_param_bytes / self.decode_ep_size
                       + self.kv_bytes_per_token * kv_tokens)
        t_mem = bytes_moved / (chips * HBM_BW * self.mbu)
        # all-to-all over ICI scales with the DP unit's batch: the B_i term
        t_comm = batch * self.a2a_bytes_per_token / ICI_BW
        return max(t_comp, t_mem) + t_comm

    def decode_step_time(self, batches: Sequence[int],
                         kvs: Sequence[int]) -> float:
        """Instance-level decode step (sync barrier across DP units)."""
        if not batches:
            return self.t_sync
        return max(self.decode_dp_time(b, k)
                   for b, k in zip(batches, kvs)) + self.t_sync

    # ------------------------------------------------------------------
    def mixed_dp_time(self, batch: int, kv_tokens: int,
                      prefill_tokens: int) -> float:
        """One UNIFIED mixed-batch iteration on one DP unit: `batch`
        decode rows plus `prefill_tokens` piggybacked chunked-prefill
        tokens in the same forward pass.

        This is where the Sarathi win lives in the roofline: the decode
        step is memory-bound on the WEIGHT sweep, so riding prefill
        compute on the same pass reuses that sweep — t_mem gains only
        the prefill tokens' KV writes, while a disjoint prefill pass
        would pay the whole weight read again.  Compute and all-to-all
        scale with the extra tokens as usual."""
        if batch <= 0 and prefill_tokens <= 0:
            return 0.0
        chips = self.chips_per_decode_dp
        flops = (2.0 * self._active_params * max(batch, 0)
                 / self.decode_ep_size)
        flops += self.prefill_flops(prefill_tokens) / self.decode_ep_size
        t_comp = flops / (chips * PEAK_FLOPS * self.mfu)
        bytes_moved = (self.active_param_bytes / self.decode_ep_size
                       + self.kv_bytes_per_token * kv_tokens
                       + self.kv_bytes_per_token * max(prefill_tokens, 0))
        t_mem = bytes_moved / (chips * HBM_BW * self.mbu)
        t_comm = ((max(batch, 0) + max(prefill_tokens, 0))
                  * self.a2a_bytes_per_token / ICI_BW)
        return max(t_comp, t_mem) + t_comm

    def mixed_step_time(self, batches: Sequence[int], kvs: Sequence[int],
                        prefill_tokens: Sequence[int]) -> float:
        """Instance-level unified step (sync barrier across DP units)."""
        if not batches:
            return self.t_sync
        return max(self.mixed_dp_time(b, k, p)
                   for b, k, p in zip(batches, kvs, prefill_tokens)
                   ) + self.t_sync

    def padding_flops_wasted(self, lens: Sequence[int],
                             pad_to: Optional[int] = None) -> float:
        """FLOPs spent on PADDING when the prompt lengths `lens` are
        formed into one batch padded to a common length (`pad_to`,
        default the batch max) — the BucketServe waste metric.  Bucketed
        formation shrinks this by co-batching near-equal lengths."""
        if not lens:
            return 0.0
        target = pad_to if pad_to is not None else max(lens)
        wasted = sum(max(target - ln, 0) for ln in lens)
        return self.prefill_flops(wasted)
