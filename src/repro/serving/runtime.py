"""ClusterRuntime — the single event loop behind every cluster topology
(paper §5 experiments) AND the real serving path.

Before this module the repo carried three separately implemented event
loops (`PrefillClusterSim`, `DecodeClusterSim`, `PDClusterSim`), each with
its own heap, poll-dedup and drain logic.  They are now thin configuration
wrappers over one runtime with pluggable planes (the EnginePlane contract,
repro.serving.plane):

  prefill plane   PrefillScheduler + PrefillEngine set
  decode plane    DecodeScheduler + DecodeEngine set
  handoff         optional prefill→decode coupling with a KV-transfer
                  latency function (the P/D-separated deployment)

Two clock sources drive the same loop:

  simulated  (realtime=False)  the default discrete-event mode: engines
             return pass/step *durations* from the cost model and the
             runtime advances a virtual clock along its heap.
  realtime   (realtime=True)   wall-clock mode for real engines
             (repro.serving.real_engine): engines return the ASYNC
             sentinel, execute jitted forwards on worker threads, and
             post completions to a RealtimeEventLoop; the runtime blocks
             in `next_event_time`-driven waits instead of busy-polling.

Event kinds on the shared heap:
  arrival      request enters the system (prefill plane, or decode plane
               directly when there is no prefill plane)
  pass_end     a prefill instance finished its non-preemptive pass
  kv_arrived   a prefill-completed request's KV cache landed on the
               decode pool (after the ICI/DCN transfer)
  step_end     a decode instance finished one generation step
  tick         scheduler-requested wake-up (staggered interval, decode
               batching window, watchdog deadline)

The runtime also owns the decode watchdog re-dispatch path: when the
decode scheduler reports a stalled instance (dispatched work but no step
completion within its watchdog budget), the instance is drained, its KV
accounting is released, and the stranded requests are re-placed on the
healthy instances through the scheduler's load-aware allocator.

SLO-aware overload control (both opt-in, see ServingConfig):

  admission   a `FlowController` gates arrivals: while the decode pool is
              saturated (every DP at its batch or KV budget) new arrivals
              are throttled — their arrival event re-enters the heap
              after a backoff — and, past their priority class's horizon,
              rejected outright (phase REJECTED, counted as settled).
  preemption  page-level swap-out: when lower-priority residents crowd
              out more urgent work (a deferred engine join on the real
              plane, a unit over its KV budget on the sim plane), victims
              chosen by `select_victims` are preempted — their KV parks
              on the handoff bus with generation state intact — their
              DPState accounting is released, and they re-enter through
              the scheduler's re-dispatch allocator exactly like
              watchdog-drained work.  Strictly-lower-priority-only
              eviction keeps the policy cycle-free; `max_preemptions`
              bounds per-request thrash.
"""
from __future__ import annotations

import heapq
import itertools
import queue
import time
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

from repro.core.decode_alloc import kv_footprint, select_victims
from repro.core.flow_control import FlowAction, FlowController
from repro.core.types import Request, RequestPhase
from repro.serving.plane import ASYNC, DecodeEngine, PrefillEngine


class EventLoop:
    """Heap of (time, seq, kind, payload); seq breaks ties FIFO."""

    def __init__(self):
        self._heap: List[Tuple[float, int, str, object]] = []
        self._seq = itertools.count()

    def push(self, t: float, kind: str, payload=None):
        heapq.heappush(self._heap, (t, next(self._seq), kind, payload))

    def pop(self):
        return heapq.heappop(self._heap)

    def __bool__(self):
        return bool(self._heap)


class RealtimeEventLoop(EventLoop):
    """Wall-clock event loop.  Heap times are seconds relative to loop
    start; engine worker threads deliver completions through `post`.
    `pop_wait` sleeps until the earlier of (next timed event, next posted
    completion) — the blocking replacement for the old server busy-wait."""

    def __init__(self):
        super().__init__()
        self._ext: "queue.Queue[Tuple[str, object]]" = queue.Queue()
        self._t0 = time.monotonic()

    def now(self) -> float:
        return time.monotonic() - self._t0

    def post(self, kind: str, payload=None) -> None:
        """Thread-safe completion delivery (engine worker threads)."""
        self._ext.put((kind, payload))

    def pop_wait(self, horizon: float, blocked: bool
                 ) -> Optional[Tuple[float, int, str, object]]:
        """Next event, or None when nothing can ever arrive (idle and no
        in-flight work) or the horizon passed.  `blocked` marks in-flight
        async work whose completion is worth waiting for."""
        while True:
            try:
                kind, payload = self._ext.get_nowait()
                return (self.now(), -1, kind, payload)
            except queue.Empty:
                pass
            now = self.now()
            if now > horizon:
                return None
            if self._heap:
                delay = self._heap[0][0] - now
                if delay <= 0:
                    t, s, k, p = heapq.heappop(self._heap)
                    return (max(t, now), s, k, p)
                wait = min(delay, horizon - now)
            elif blocked:
                wait = horizon - now
            else:
                return None
            try:
                kind, payload = self._ext.get(timeout=max(wait, 0.0))
                return (self.now(), -1, kind, payload)
            except queue.Empty:
                continue


class ClusterRuntime:
    def __init__(
        self,
        state,
        *,
        prefill_sched=None,
        prefill_instances: Optional[Sequence[PrefillEngine]] = None,
        decode_sched=None,
        decode_instances: Optional[Sequence[DecodeEngine]] = None,
        transfer_time=None,            # callable(Request) -> seconds
        snapshot_every: int = 0,
        realtime: bool = False,
        flow: Optional[FlowController] = None,
        preemption: bool = False,
        max_preemptions: int = 2,
        readmit_frac: float = 0.7,
    ):
        if prefill_sched is None and decode_sched is None:
            raise ValueError("runtime needs at least one plane")
        self.state = state
        self.psched = prefill_sched
        self.prefill = list(prefill_instances or [])
        self.dsched = decode_sched
        self.decode = list(decode_instances or [])
        self.transfer_time = transfer_time
        self.snapshot_every = snapshot_every
        self.realtime = realtime
        self._dp2dinst = {d.dp_id: d.instance_id
                          for d in state.decode_dps} if self.decode else {}
        self._pass_start: Dict[int, float] = {}
        self._next_tick: Optional[float] = None
        self._inflight = 0      # async passes/steps outstanding (realtime)
        # decode observability (Fig 7/8 timelines)
        self.kv_timeline: List[List[int]] = []
        self.batch_timeline: List[List[int]] = []
        self.redispatched: List[Request] = []
        self._steps = 0
        # SLO-aware overload control (opt-in)
        self.flow = flow
        self.preemption = preemption
        self.max_preemptions = max_preemptions
        self.readmit_frac = readmit_frac
        self.preempted: List[Request] = []      # every swap-out event
        self.rejected: List[Request] = []       # flow-control rejections
        self._parked: List[Request] = []        # swapped out, awaiting room

    # -- helpers -----------------------------------------------------------

    def _schedule_tick(self, ev: EventLoop, t: Optional[float],
                       now: float):
        """Dedup: keep only the earliest pending tick; later wake-ups are
        re-derived from next_event_time() once that tick fires.  A tick at
        (or before) `now` is dropped — the drive section already polled at
        `now`, so re-ticking the same instant cannot make progress and
        would livelock the loop."""
        if t is None or t <= now + 1e-12:
            return
        if self._next_tick is None or t < self._next_tick - 1e-12:
            self._next_tick = t
            ev.push(t, "tick", None)

    def _place(self, placements: Optional[Dict[int, List[Request]]],
               now: float):
        if not placements:
            return
        for dp_id, reqs in placements.items():
            inst = self.decode[self._dp2dinst[dp_id]]
            for r in reqs:
                inst.admit(dp_id, r)
        if self.dsched is not None and hasattr(self.dsched, "on_placed"):
            self.dsched.on_placed(placements, now)

    def _handoff(self, req: Request, now: float):
        """Request enters the decode plane (fresh arrival or KV arrival).

        In the cost-model sim the decode plane emits every token, so the
        provisional prefill-completion stamp is cleared and TTFT lands on
        the first decode step.  On the real plane the first token was
        PHYSICALLY produced by the prefill engine (req.generated == 1 at
        handoff) — that stamp is the true TTFT and must survive."""
        if self.psched is not None and req.generated == 0:
            req.first_token_time = None      # sim: TTFT is set by decode
        req.phase = RequestPhase.DECODING
        self._place(self.dsched.on_handoff(req, now), now)

    def _snapshot(self):
        if self.snapshot_every and self._steps % self.snapshot_every == 0:
            self.kv_timeline.append(
                [d.kv_tokens for d in self.state.decode_dps])
            self.batch_timeline.append(
                [d.batch for d in self.state.decode_dps])

    def _redispatch_stalled(self, now: float):
        """Watchdog path: pull stranded work off wedged decode instances
        and re-place it on healthy ones."""
        if self.dsched is None or not hasattr(self.dsched,
                                              "stalled_instances"):
            return None
        stalled = self.dsched.stalled_instances(now)
        if not stalled:
            return None
        by_id = {d.dp_id: d for d in self.state.decode_dps}
        orphans: List[Request] = []
        for iid in stalled:
            drained = self.decode[iid].drain()
            for dp_id, reqs in drained.items():
                st = by_id[dp_id]
                for r in reqs:
                    st.release(r.input_len + r.generated,
                               reserve_len=r.input_len + r.output_len)
                    r.assigned_dp = None
                    r.migrations += 1
                    orphans.append(r)
        if orphans:
            self.redispatched.extend(orphans)
            return self.dsched.place_redispatch(orphans, now)
        return None

    def _decode_saturated(self) -> bool:
        """Admission-gate predicate: every decode DP is at its batch cap
        or KV budget — there is nowhere to put new work."""
        dps = self.state.decode_dps
        if not dps:
            return False
        return all(d.batch >= d.max_batch or d.kv_occupancy >= d.kv_budget
                   for d in dps)

    def _preempt_pressure(self, now: float):
        """SLO-aware preemption: free capacity for more urgent waiters by
        swapping strictly-lower-priority residents out.

        Two pressure signals, one victim policy (`select_victims`):
          real plane   `pending_waits()` — a scheduler-admitted join the
                       engine has deferred for device-side capacity; the
                       waiter's own priority bounds who may be evicted.
          sim plane    a DP over its KV budget (the cost-model engines
                       admit unconditionally); the most urgent resident
                       class bounds eviction.
        Victims are released from DPState accounting (exactly the
        watchdog-drain bookkeeping) and PARKED — the swap-to-host model:
        their KV leaves the device (on the real plane it rides the
        handoff bus and re-joins dense with generation state intact) and
        `_readmit_parked` returns them through the scheduler's
        re-dispatch allocator once a DP can hold them within budget."""
        if self.dsched is None or not self.decode:
            return None
        by_id = {d.dp_id: d for d in self.state.decode_dps}
        bs = getattr(self.state, "block_size", 0) or 0
        victims: List[Request] = []
        for inst in self.decode:
            for waiter in sorted(inst.pending_waits(),
                                 key=lambda r: (r.priority, r.arrival_time)):
                dp_id = waiter.assigned_dp
                if dp_id is None:
                    continue
                need = kv_footprint(waiter, bs)
                free = inst.free_kv_tokens(dp_id, tokens=waiter.tokens)
                if free is not None:
                    need -= free
                if need <= 0:
                    continue        # capacity already there; join retries
                residents = [r for r in inst.running.get(dp_id, [])
                             if r.preemptions < self.max_preemptions]
                for v in select_victims(residents, need, bs,
                                        max_priority=waiter.priority):
                    got = inst.preempt(v.rid)
                    if got is None:
                        break       # step in flight — retry next event
                    victims.append(got)
            for dp_id in inst.dp_ids:
                st = by_id[dp_id]
                over = st.kv_occupancy - st.kv_budget
                if over <= 0:
                    continue
                residents = [r for r in inst.running.get(dp_id, [])
                             if r.preemptions < self.max_preemptions]
                if not residents:
                    continue
                top = min(r.priority for r in residents)
                for v in select_victims(residents, over, bs,
                                        max_priority=top):
                    got = inst.preempt(v.rid)
                    if got is None:
                        break
                    victims.append(got)
        if not victims:
            return
        for r in victims:
            st = by_id[r.assigned_dp]
            st.release(r.input_len + r.generated,
                       reserve_len=r.input_len + r.output_len)
            r.assigned_dp = None
            r.preemptions += 1
            r.phase = RequestPhase.PREEMPTED
        self.preempted.extend(victims)
        self._parked.extend(victims)

    def _readmit_parked(self, now: float):
        """Re-admit parked (swapped-out) requests once pressure drops: a
        parked request re-enters — most urgent first — when some DP can
        hold its whole KV footprint within `readmit_frac` of its budget.
        The fraction is hysteresis: re-admitting the moment occupancy
        dips under 100% puts the victim straight back into the pressure
        that evicted it (swap thrash); waiting for real headroom lets
        the spike pass.  Placement goes through the scheduler's
        re-dispatch allocator, i.e. the normal join path."""
        if not self._parked:
            return None
        bs = getattr(self.state, "block_size", 0) or 0
        self._parked.sort(key=lambda r: (r.priority, r.arrival_time))
        ready: List[Request] = []
        kept: List[Request] = []
        for r in self._parked:
            foot = kv_footprint(r, bs)
            if any(d.batch < d.max_batch
                   and d.kv_occupancy + foot <= d.kv_budget
                   * self.readmit_frac
                   for d in self.state.decode_dps):
                ready.append(r)
            else:
                kept.append(r)
        if not ready:
            return None
        self._parked = kept
        placements = self.dsched.place_redispatch(ready, now)
        if placements:
            for reqs in placements.values():
                for r in reqs:
                    r.phase = RequestPhase.DECODING
        return placements

    def _all_settled(self, template: Sequence[Request]) -> bool:
        return all(r.finish_time is not None
                   or r.phase == RequestPhase.REJECTED for r in template)

    # -- the loop ----------------------------------------------------------

    def run(self, requests: Sequence[Request], duration: float, *,
            horizon: Optional[float] = None, closed_loop: int = 0) -> float:
        """Drive all planes until the heap drains or `horizon` passes.
        Returns the final clock (virtual seconds, or wall seconds since
        loop start in realtime mode).  `closed_loop` (decode-only mode)
        holds that many concurrent requests: each finish admits the next
        from the template list (paper §5.2.2)."""
        ev = RealtimeEventLoop() if self.realtime else EventLoop()
        if self.realtime:
            for inst in itertools.chain(self.prefill, self.decode):
                if hasattr(inst, "bind_loop"):
                    inst.bind_loop(ev)
        self._next_tick = None
        self._inflight = 0
        for sched in (self.psched, self.dsched):
            if sched is not None and hasattr(sched, "reset_clock"):
                sched.reset_clock()     # this run's clock starts at 0
        template = list(requests)
        pool: Iterator[Request] = iter(())
        if closed_loop:
            n0 = min(len(template), closed_loop)
            pool = iter(template[n0:])
            for r in template[:n0]:
                r.arrival_time = 0.0
                ev.push(0.0, "arrival", r)
        else:
            for r in template:
                ev.push(r.arrival_time, "arrival", r)
        now = 0.0
        if horizon is None:
            horizon = duration * 20 + 60.0
        while True:
            if self.realtime:
                item = ev.pop_wait(horizon, blocked=self._inflight > 0)
                if item is None:
                    break
            else:
                if not ev:
                    break
                item = ev.pop()
            now, _, kind, payload = item
            if now > horizon:
                break
            if kind == "arrival":
                req: Request = payload
                act = FlowAction.ADMIT
                if self.flow is not None and self.decode:
                    act = self.flow.gate(req, self._decode_saturated())
                if act == FlowAction.THROTTLE:
                    ev.push(now + self.flow.backoff(req.wait_cycles),
                            "arrival", req)
                elif act == FlowAction.REJECT:
                    req.phase = RequestPhase.REJECTED
                    self.rejected.append(req)
                elif self.psched is not None:
                    self.psched.on_arrival(req, now)
                else:
                    self._handoff(req, now)
            elif kind == "pass_end":
                inst: PrefillEngine = payload
                if self.realtime:
                    self._inflight -= 1
                start = self._pass_start.pop(inst.instance_id)
                res = inst.finish_pass(now)
                for e in res.end_forwards:
                    e.exec_time = now - start
                    self.psched.on_end_forward(e)
                if self.dsched is not None:
                    for req in res.completed:
                        delay = (self.transfer_time(req)
                                 if self.transfer_time else 0.0)
                        ev.push(now + delay, "kv_arrived", req)
            elif kind == "kv_arrived":
                self._handoff(payload, now)
            elif kind == "step_end":
                dinst, epoch, step_dur = payload
                if self.realtime:
                    self._inflight -= 1
                if epoch != dinst.epoch:
                    pass        # stale: the instance was drained mid-step
                else:
                    done = dinst.finish_step(now, self.state.decode_dps)
                    if self.dsched is not None and hasattr(self.dsched,
                                                           "on_step_end"):
                        self.dsched.on_step_end(dinst.instance_id, now,
                                                step_time=step_dur)
                    if closed_loop:
                        for _ in done:
                            nxt = next(pool, None)
                            if nxt is not None:
                                nxt.arrival_time = now
                                ev.push(now, "arrival", nxt)
                    self._steps += 1
                    self._snapshot()
            elif kind == "tick":
                if (self._next_tick is not None
                        and now >= self._next_tick - 1e-9):
                    self._next_tick = None
            # drive every plane after any event ----------------------------
            if self.psched is not None:
                for cmd in self.psched.poll(now):
                    self.prefill[cmd.instance_id].enqueue(cmd, now)
                for inst in self.prefill:
                    dur = inst.start_pass(now)
                    if dur is ASYNC:
                        self._pass_start[inst.instance_id] = now
                        self._inflight += 1
                    elif dur is not None:
                        self._pass_start[inst.instance_id] = now
                        ev.push(now + dur, "pass_end", inst)
            if self.dsched is not None:
                self._place(self.dsched.poll(now), now)
                self._place(self._redispatch_stalled(now), now)
                if self.preemption:
                    self._place(self._readmit_parked(now), now)
                    self._preempt_pressure(now)
                for dinst in self.decode:
                    dur = dinst.start_step(self.state.decode_dps, now)
                    if dur is ASYNC:
                        self._inflight += 1
                    elif dur is not None:
                        ev.push(now + dur, "step_end",
                                (dinst, dinst.epoch, dur))
            # wake-ups -----------------------------------------------------
            for sched in (self.psched, self.dsched):
                if sched is not None:
                    self._schedule_tick(ev, sched.next_event_time(now), now)
            # realtime early exit: every request settled — don't sleep out
            # residual ticks
            if (self.realtime and not closed_loop and template
                    and self._inflight == 0 and self._all_settled(template)):
                break
        return now

    # -- aggregate stats ---------------------------------------------------

    @property
    def prefill_util(self) -> float:
        return (sum(i.tokens_processed for i in self.prefill)
                / max(sum(i.capacity_offered for i in self.prefill), 1))

    @property
    def tokens_generated(self) -> int:
        return sum(i.tokens_generated for i in self.decode)
