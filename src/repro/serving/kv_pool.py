"""Paged KV block allocator — the host-side control plane of the paged
decode cache (the device-side pool layout lives in `models.model`'s
`init_paged_cache` family).

A `BlockPool` owns a fixed set of physical KV blocks of `block_size`
tokens each.  Requests hold *block tables* (lists of physical block ids)
instead of a padded `max_len` slot, so a DP unit's admission limit is its
free-block count, not its slot count — the same mechanism vLLM-style
PagedAttention and Sarathi-Serve use to keep decode concurrency high at a
fixed KV memory budget.

Physical block 0 is RESERVED as the null block: inactive batch rows and
padding entries of a block table scatter their garbage writes there, so
the pool never hands it out.  The allocator is deliberately strict —
double-free and foreign-id frees raise instead of corrupting the free
list — because the property suite (tests/test_kv_pool.py) drives it with
random join/take/free sequences and any silent self-healing would mask a
real leak in the engine.
"""
from __future__ import annotations

from typing import Iterable, List, Sequence

from repro.core.types import blocks_for_tokens

NULL_BLOCK = 0


class OutOfBlocks(RuntimeError):
    """Allocation request exceeds the pool's free-block count."""


class BlockPool:
    """Fixed-capacity physical KV block allocator (one per decode DP).

    ids run 1..num_blocks-1 (0 is the reserved null block); `alloc`
    returns the lowest free ids first so reuse is deterministic and the
    property tests can assert freed pages come back.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("pool needs >= 2 blocks (block 0 is reserved)")
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # sorted free list => deterministic lowest-id-first reuse
        self._free: List[int] = list(range(1, num_blocks))
        self._used: set = set()

    # -- capacity probes -------------------------------------------------
    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def used_count(self) -> int:
        return len(self._used)

    @property
    def capacity_tokens(self) -> int:
        """Usable KV tokens (the null block is dead memory)."""
        return (self.num_blocks - 1) * self.block_size

    def blocks_for(self, tokens: int) -> int:
        """Blocks needed to hold `tokens` KV entries (the shared ceiling
        rule — scheduler reservations use the same function)."""
        return blocks_for_tokens(tokens, self.block_size)

    def can_alloc(self, tokens: int) -> bool:
        return self.blocks_for(tokens) <= self.free_count

    # -- alloc / free ----------------------------------------------------
    def alloc(self, n: int) -> List[int]:
        """Take `n` blocks off the free list (lowest ids first)."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            raise OutOfBlocks(
                f"need {n} blocks, {len(self._free)} free "
                f"(pool of {self.num_blocks})")
        taken, self._free = self._free[:n], self._free[n:]
        self._used.update(taken)
        return taken

    def alloc_for(self, tokens: int) -> List[int]:
        return self.alloc(self.blocks_for(tokens))

    def free(self, ids: Iterable[int]) -> None:
        """Return blocks to the pool.  Raises on double-free, the null
        block, or ids the pool never issued."""
        for b in ids:
            if b == NULL_BLOCK:
                raise ValueError("cannot free the reserved null block")
            if b not in self._used:
                raise ValueError(f"free of unallocated block {b}")
            self._used.discard(b)
            self._free.append(b)
        self._free.sort()

    # -- invariants (asserted by the property suite) ---------------------
    def check(self) -> None:
        """Conservation: every non-null block is free XOR used, once."""
        free = self._free
        assert len(set(free)) == len(free), "duplicate ids on the free list"
        assert not (set(free) & self._used), "block both free and used"
        assert NULL_BLOCK not in set(free) | self._used, "null block leaked"
        assert len(free) + len(self._used) == self.num_blocks - 1, (
            f"leak: {len(free)} free + {len(self._used)} used != "
            f"{self.num_blocks - 1}")


def pad_block_table(ids: Sequence[int], width: int) -> List[int]:
    """Fixed-width block-table row: real ids then -1 padding (the jit'd
    cache surgery takes a constant-shape row; -1 marks unset slots and
    routes scatter traffic to the null block)."""
    if len(ids) > width:
        raise ValueError(f"{len(ids)} blocks exceed table width {width}")
    return list(ids) + [-1] * (width - len(ids))
