"""Paged KV block allocator — the host-side control plane of the paged
decode cache (the device-side pool layout lives in `models.model`'s
`init_paged_cache` family).

A `BlockPool` owns a fixed set of physical KV blocks of `block_size`
tokens each.  Requests hold *block tables* (lists of physical block ids)
instead of a padded `max_len` slot, so a DP unit's admission limit is its
free-block count, not its slot count — the same mechanism vLLM-style
PagedAttention and Sarathi-Serve use to keep decode concurrency high at a
fixed KV memory budget.

Blocks are REFCOUNTED so several block tables can point at the same
read-only physical block (prefix sharing): `alloc` hands a block out with
one reference, `incref` adds holders, and `free` drops one reference per
id — a block returns to the free heap only when its last holder lets go.
Writers must never scatter into a block whose refcount exceeds one; the
engine copies it first (copy-on-write, see `models.model.paged_copy_block`).
The pool also keeps a content key → block map (`bind`/`lookup`) so a
radix prefix index can resolve "these `block_size` tokens at this
position" to an existing physical page in O(1); bindings die with the
block's last reference.

Physical block 0 is RESERVED as the null block: inactive batch rows and
padding entries of a block table scatter their garbage writes there, so
the pool never hands it out.  The allocator is deliberately strict —
double-free and foreign-id frees raise instead of corrupting the free
heap — because the property suite (tests/test_kv_pool.py,
tests/test_page_sharing.py) drives it with random join/take/share/free
sequences and any silent self-healing would mask a real leak in the
engine.
"""
from __future__ import annotations

import heapq
from typing import Dict, Hashable, Iterable, List, Optional, Sequence

from repro.core.types import blocks_for_tokens

NULL_BLOCK = 0


class OutOfBlocks(RuntimeError):
    """Allocation request exceeds the pool's free-block count."""


class BlockPool:
    """Fixed-capacity physical KV block allocator (one per decode DP).

    ids run base+1..base+num_blocks-1 (0 is the reserved null block, and
    id `base` of a non-zero-based pool is never issued — it aliases
    another pool's range boundary in the merged sharded cache); `alloc`
    returns the lowest free ids first so reuse is deterministic and the
    property tests can assert freed pages come back.  The free store is
    a binary heap: alloc/free are O(log n) per block where the old
    sorted-list store re-sorted the whole list on every free.

    `base` exists for the SHARDED real plane: every decode DP keeps its
    own allocator (admission control stays per-DP), but all DPs' blocks
    live in ONE mesh-sharded device pool — DP d gets
    `BlockPool(num_blocks, bs, base=d*num_blocks)` so its physical ids
    index its own shard of the merged pool dimension and can never
    collide with another DP's table rows.
    """

    def __init__(self, num_blocks: int, block_size: int, base: int = 0):
        if num_blocks < 2:
            raise ValueError("pool needs >= 2 blocks (block 0 is reserved)")
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        if base < 0:
            raise ValueError("base must be >= 0")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.base = base
        # min-heap => deterministic lowest-id-first reuse (a sorted range
        # is already a valid heap, so no heapify needed here)
        self._free: List[int] = list(range(base + 1, base + num_blocks))
        self._ref: Dict[int, int] = {}          # block id -> holders (>=1)
        # content-addressed page map: key -> block and its inverse, so
        # prefix-cache admission resolves cached token blocks to physical
        # pages without walking engine state
        self._block_of: Dict[Hashable, int] = {}
        self._key_of: Dict[int, Hashable] = {}

    # -- capacity probes -------------------------------------------------
    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def used_count(self) -> int:
        return len(self._ref)

    @property
    def capacity_tokens(self) -> int:
        """Usable KV tokens (the null block is dead memory)."""
        return (self.num_blocks - 1) * self.block_size

    def blocks_for(self, tokens: int) -> int:
        """Blocks needed to hold `tokens` KV entries (the shared ceiling
        rule — scheduler reservations use the same function)."""
        return blocks_for_tokens(tokens, self.block_size)

    def can_alloc(self, tokens: int) -> bool:
        return self.blocks_for(tokens) <= self.free_count

    # -- alloc / refcount / free -----------------------------------------
    def alloc(self, n: int) -> List[int]:
        """Take `n` blocks off the free heap (lowest ids first), each
        with a single reference held by the caller."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            raise OutOfBlocks(
                f"need {n} blocks, {len(self._free)} free "
                f"(pool of {self.num_blocks})")
        taken = [heapq.heappop(self._free) for _ in range(n)]
        for b in taken:
            self._ref[b] = 1
        return taken

    def alloc_for(self, tokens: int) -> List[int]:
        return self.alloc(self.blocks_for(tokens))

    def incref(self, ids: Iterable[int]) -> None:
        """Add one holder per id (block-table sharing).  Only live blocks
        can gain references."""
        for b in ids:
            if b not in self._ref:
                raise ValueError(f"incref of unallocated block {b}")
            self._ref[b] += 1

    def refcount(self, block: int) -> int:
        return self._ref.get(block, 0)

    def is_shared(self, block: int) -> bool:
        """True when a write into `block` needs copy-on-write."""
        return self._ref.get(block, 0) > 1

    def free(self, ids: Iterable[int]) -> None:
        """Drop one reference per id; a block returns to the pool only
        when its last reference is dropped (its content binding dies with
        it).  Raises on over-free, the null block, or ids the pool never
        issued."""
        for b in ids:
            if b == NULL_BLOCK:
                raise ValueError("cannot free the reserved null block")
            r = self._ref.get(b)
            if r is None:
                raise ValueError(f"free of unallocated block {b}")
            if r > 1:
                self._ref[b] = r - 1
                continue
            del self._ref[b]
            key = self._key_of.pop(b, None)
            if key is not None:
                self._block_of.pop(key, None)
            heapq.heappush(self._free, b)

    # -- content-addressed page map --------------------------------------
    def bind(self, key: Hashable, block: int) -> None:
        """Publish `block` as the physical page holding the content named
        by `key`.  First binding wins: rebinding an already-published key
        to a different live block is a no-op (the existing page stays the
        canonical copy), so concurrent prefills of the same prefix
        converge on one page."""
        if block not in self._ref:
            raise ValueError(f"bind of unallocated block {block}")
        if key in self._block_of:
            return
        self._block_of[key] = block
        self._key_of[block] = key

    def lookup(self, key: Hashable) -> Optional[int]:
        """Physical block holding `key`'s content, or None."""
        return self._block_of.get(key)

    # -- invariants (asserted by the property suite) ---------------------
    def check(self) -> None:
        """Conservation: every non-null block is free XOR referenced,
        once; refcounts are positive; content bindings point at live
        blocks and are mutually consistent."""
        free = self._free
        used = set(self._ref)
        assert len(set(free)) == len(free), "duplicate ids on the free heap"
        assert not (set(free) & used), "block both free and referenced"
        assert NULL_BLOCK not in set(free) | used, "null block leaked"
        assert len(free) + len(used) == self.num_blocks - 1, (
            f"leak: {len(free)} free + {len(used)} used != "
            f"{self.num_blocks - 1}")
        assert all(r >= 1 for r in self._ref.values()), "dead refcount entry"
        for key, b in self._block_of.items():
            assert b in self._ref, f"binding {key!r} -> freed block {b}"
            assert self._key_of.get(b) == key, "content map out of sync"
        assert len(self._key_of) == len(self._block_of), (
            "content map out of sync")


def pad_block_table(ids: Sequence[int], width: int) -> List[int]:
    """Fixed-width block-table row: real ids then -1 padding (the jit'd
    cache surgery takes a constant-shape row; -1 marks unset slots and
    routes scatter traffic to the null block)."""
    if len(ids) > width:
        raise ValueError(f"{len(ids)} blocks exceed table width {width}")
    return list(ids) + [-1] * (width - len(ids))
