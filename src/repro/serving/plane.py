"""The EnginePlane contract — the single engine-facing interface behind
`ClusterRuntime`.

A *plane* is a scheduler plus a set of engine instances.  The runtime is
the only driver: it forwards scheduler decisions to the instances and
turns instance completions back into scheduler feedback.  Everything an
engine must expose to participate is defined here, and BOTH backends
satisfy it:

  simulated   SimPrefillInstance / SimDecodeInstance (serving.engine) —
              pass/step durations come from the roofline cost model and
              the runtime advances a virtual clock.
  real        RealPrefillEngine / RealDecodeEngine (serving.real_engine)
              — passes/steps are actual jitted JAX forwards executed on a
              worker thread; the runtime uses a wall clock
              (RealtimeEventLoop) and blocks until completions are
              posted.

The split point is the return value of `start_pass` / `start_step`:

  float    the pass/step will take this many (virtual) seconds — the
           runtime schedules the matching `pass_end` / `step_end` event
           on its heap (simulated plane).
  ASYNC    the pass/step was submitted to a worker thread — the engine
           will post `("pass_end", self)` / `("step_end", (self, epoch,
           dur))` to the runtime's realtime loop when the forwards
           complete (real plane).
  None     idle (no work, or a pass/step already in flight).

`finish_pass` / `finish_step` are ALWAYS called on the runtime thread, so
all scheduler-visible state mutation (Request bookkeeping, DecodeDPState
accounting, KV handoff publication) is single-threaded; worker threads
only run pure JAX computations on snapshots taken at submit time.

MESH-NATIVE REAL ENGINES: the same real classes become sharded when
their `EngineSpec` carries a `jax.sharding.Mesh` — per-DP state stays
Python-side, but each pass/step submits ONE cross-device XLA program
(params, merged paged cache, and batch rows sharded over the mesh's
"data" axis; MoE routed through the explicit EP all-to-all), so the
instance-level sync barrier this contract models is physically real.
All multi-device work of a deployment serializes behind the spec's mesh
lock — one device set, one collective program at a time (see
DESIGN.md "Sharded real plane").
"""
from __future__ import annotations

import abc
import dataclasses
from typing import Dict, List, Optional, Sequence, Union

from repro.core.types import DispatchCommand, EndForward, Request


class _Async:
    """Sentinel returned by real engines from start_pass/start_step."""

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return "<ASYNC>"


ASYNC = _Async()

#: what start_pass / start_step may return
StartResult = Union[float, _Async, None]


@dataclasses.dataclass
class PassResult:
    """Outcome of one prefill pass (sim and real)."""
    end_forwards: List[EndForward]
    completed: List[Request]      # prefill fully done at pass end
    processed_per_dp: Dict[int, int]


class PrefillEngine(abc.ABC):
    """One prefill instance: a non-preemptive discrete batch processor
    over its DP units (§3.2)."""

    instance_id: int
    dp_ids: List[int]

    @abc.abstractmethod
    def enqueue(self, cmd: DispatchCommand, now: float) -> None:
        """Accept a scheduler dispatch into the per-DP device queues."""

    @abc.abstractmethod
    def start_pass(self, now: float) -> StartResult:
        """Begin a forward pass over the queued work (see module doc)."""

    @abc.abstractmethod
    def finish_pass(self, now: float) -> PassResult:
        """Complete the pass begun by start_pass (runtime thread only)."""

    @abc.abstractmethod
    def has_work(self) -> bool:
        """Readiness probe: any queued tokens on any DP?"""

    @abc.abstractmethod
    def backlog(self, dp_id: int) -> int:
        """Backlog probe: queued tokens on one DP (EndForward payload)."""


class DecodeEngine(abc.ABC):
    """One decode instance: DP units step together behind the sync
    barrier; requests join on KV handoff and leave on completion.

    KV ACCOUNTING IS BLOCK-GRANULAR when the deployment pages its caches
    (ServingConfig.block_size > 0): the scheduler-side `DecodeDPState`
    tracks reserved blocks (`kv_blocks` / `kv_occupancy`) next to the
    exact token load, budgets and the `sbs-la` load balancer read
    `kv_occupancy`, and an engine admits a handed-off request only while
    its DP's free-BLOCK count covers the request's lifetime pages — not
    merely while a batch slot is free.  `free_kv_tokens` exposes that
    device-side headroom to drivers/diagnostics; padded engines report
    free slots × max_len."""

    instance_id: int
    dp_ids: List[int]
    epoch: int          # bumped by drain(); invalidates in-flight steps

    def free_kv_tokens(self, dp_id: int,
                       tokens: Optional[Sequence[int]] = None
                       ) -> Optional[int]:
        """Admission headroom of one DP in KV tokens (block-granular on
        paged engines); None when the backend has no physical cache (the
        cost-model sims — their capacity lives in DecodeDPState).  With
        `tokens` (a prospective request's prompt ids), page-sharing
        engines additionally credit the claimable block-aligned prefix
        already resident in the DP's binder — the same credit the
        dispatch-side `EngineBackedPrefixIndex` grants, so scheduler and
        engine agree on capacity under heavy sharing."""
        return None

    def preempt(self, rid: int) -> Optional[Request]:
        """Page-level preemption: swap ONE resident request out (park
        its KV + generation state for later re-join) and free its
        slot/pages.  Returns the request, or None when it is not
        resident or a step is in flight (the caller retries next
        cycle).  The caller owns releasing DecodeDPState accounting and
        re-admitting the victim through the normal join path."""
        return None

    def pending_waits(self) -> List[Request]:
        """Requests admitted by the scheduler but still waiting for
        device-side capacity (deferred joins).  Empty on backends that
        admit unconditionally (the cost-model sims)."""
        return []

    @abc.abstractmethod
    def admit(self, dp_id: int, req: Request) -> None:
        """Place a handed-off request onto one of this instance's DPs."""

    @abc.abstractmethod
    def start_step(self, dp_states: Sequence, now: Optional[float] = None
                   ) -> StartResult:
        """Begin one generation step over all running requests."""

    @abc.abstractmethod
    def finish_step(self, now: float, dp_states: Sequence) -> List[Request]:
        """Complete the step; returns the requests that finished."""

    @abc.abstractmethod
    def has_work(self) -> bool:
        """Readiness probe: any running (or pending-join) requests?"""

    @abc.abstractmethod
    def drain(self) -> Dict[int, List[Request]]:
        """Watchdog path: strip all resident work off this instance."""


class UnifiedEngine(DecodeEngine):
    """One UNIFIED mixed-batch instance: a decode engine that also owns
    its requests' chunked prefill, so prompts and decode rows share the
    same engine step (Sarathi-style piggybacking — the plane that kills
    the disjoint-loop decode stall).

    Contract deltas vs a plain DecodeEngine:

      * `admit` additionally accepts RAW requests (remaining_prefill >
        0, no published generation state).  The engine stages them as
        prefilling residents — KV pages reserved for the full lifetime
        up front — and runs their chunks out of the leftover per-step
        token budget (`chunk − decode_rows`) of the SAME forward the
        decode rows run in.  Completing the prompt emits the first
        token from inside the step; the request then graduates to the
        decode rows without any KV handoff (same pool, same DP).
      * STARVATION BOUND: when decode rows exhaust the budget for
        `starve_limit` consecutive steps while prefill is pending, the
        next step grants a minimum chunk regardless of decode load —
        prefill may lag, never be locked out.
      * A unified deployment runs DECODE-PLANE-ONLY under the runtime
        (`psched=None`): arrivals hand off directly to the decode
        scheduler, and `immediate`/`sbs`/`sbs-la` drive it unchanged.

    Both backends implement this: `SimUnifiedInstance` (cost-model
    clocked, `CostModel.mixed_step_time`) and `RealUnifiedEngine`
    (jitted `mixed_step`, paged cache only)."""

    def prefill_backlog(self) -> int:
        """Prompt tokens still to be prefilled across all DPs."""
        return 0
