"""Synthetic workloads matching the paper's §5 evaluation traffic.

- short:      input lengths 0–3K tokens, mean ≈ 1K   (Fig 6a; Chunk 3K)
- long:       input lengths 3K–64K tokens, mean ≈ 6.7K (Fig 6b; Chunk 16K)
- decode:     combined in+out ≈ 2.5K tokens, avg batch 35 (Fig 7/8)
- bursty:     short lengths under a Markov-modulated Poisson process —
              on/off arrival bursts with the same long-run rate (flash
              crowds; stresses the staggered clock and flow control)
- decode_burst: decode-heavy bursty — long generations keep every DP
              populated while MMPP prompt bursts arrive on top (the
              mixed-batch ITL scenario: disjoint prefill stalls the
              resident decode rows, piggybacking does not)
- heavy_tail: long-context heavy-tail (lognormal σ=1.6, up to 128K) —
              a few huge documents amid chat traffic (stresses chunking
              and KV-load balance)
- shared_prefix: multi-tenant traffic where every request opens with its
              tenant's system prompt; tenants are Zipf-popular, so a few
              hot prompts dominate (the prefix-cache / page-sharing
              scenario — hit rate tracks Zipf mass × prefix fraction)
- overload_spike: mixed SLO classes under a hard flash crowd (5× peak):
              interactive chat, standard traffic and batch jobs share the
              pool, so overload control has real choices to make (the
              preemption / flow-control / goodput scenario)
- diurnal:    the same class mix under a slow sinusoidal rate swell —
              a compressed day: the pool saturates near the crest and
              recovers in the trough (tests that throttled work admits
              again and preempted work completes)

Arrivals are Poisson (the M in the paper's M/D/S analysis); bursty
workloads modulate the rate between a high and a low state; diurnal
workloads thin a peak-rate Poisson stream against a sinusoid.

Priority classes: `class_mix` assigns each request an SLO class
(core.types.SLO_CLASSES — name, priority, e2e deadline) with the given
probabilities.  An empty mix leaves every request in the default class,
which keeps the legacy scenarios byte-identical.
"""
from __future__ import annotations

import bisect
import dataclasses
import math
import random
from typing import Dict, Iterator, List, Optional

from repro.core.types import Request, SLO_CLASSES


@dataclasses.dataclass
class WorkloadSpec:
    name: str
    min_len: int
    max_len: int
    mean_len: float
    out_mean: int = 200
    sigma: float = 0.8            # lognormal shape (tail heaviness)
    # arrival-process modulation (1.0 => plain Poisson)
    burst_factor: float = 1.0     # peak rate = burst_factor × mean rate
    burst_duty: float = 0.3       # fraction of each cycle at peak rate
    burst_period: float = 2.0     # seconds per on/off cycle
    # multi-tenant system prompts (n_tenants > 0 => every request starts
    # with its tenant's prompt; tenant popularity is Zipf(tenant_zipf))
    n_tenants: int = 0
    tenant_zipf: float = 1.2
    tenant_prefix_len: int = 384
    # SLO class mix: class name -> probability (empty = all default class)
    class_mix: Dict[str, float] = dataclasses.field(default_factory=dict)
    # sinusoidal rate modulation (diurnal): peak rate = qps, trough rate =
    # qps * diurnal_floor, one full cycle per diurnal_period seconds
    diurnal_period: float = 0.0
    diurnal_floor: float = 0.1


SHORT = WorkloadSpec("short", 16, 3000, 1000.0)
LONG = WorkloadSpec("long", 3000, 64000, 6700.0)
DECODE = WorkloadSpec("decode", 512, 4096, 2000.0, out_mean=500)
BURSTY = WorkloadSpec("bursty", 16, 3000, 1000.0,
                      burst_factor=3.0, burst_duty=0.25, burst_period=2.0)
HEAVY_TAIL = WorkloadSpec("heavy_tail", 64, 131072, 2500.0, sigma=1.6)
SHARED_PREFIX = WorkloadSpec("shared_prefix", 256, 3000, 1000.0,
                             n_tenants=24, tenant_zipf=1.2,
                             tenant_prefix_len=384)
# decode-heavy bursty traffic: long generations keep every decode DP
# populated while MMPP prompt bursts arrive on top — each burst's prefill
# must run WHILE decodes are resident, which is exactly where a disjoint
# prefill/decode loop stalls the resident rows (the ITL-p99 bubble the
# unified mixed-batch plane removes)
DECODE_BURST = WorkloadSpec("decode_burst", 512, 8000, 2500.0,
                            out_mean=600,
                            burst_factor=4.0, burst_duty=0.2,
                            burst_period=3.0)
_CLASS_MIX = {"interactive": 0.35, "standard": 0.45, "batch": 0.20}
OVERLOAD_SPIKE = WorkloadSpec("overload_spike", 16, 3000, 1000.0,
                              out_mean=300,
                              burst_factor=5.0, burst_duty=0.15,
                              burst_period=4.0, class_mix=_CLASS_MIX)
DIURNAL = WorkloadSpec("diurnal", 16, 3000, 1000.0, out_mean=300,
                       diurnal_period=20.0, diurnal_floor=0.15,
                       class_mix=_CLASS_MIX)

SPECS = {"short": SHORT, "long": LONG, "decode": DECODE,
         "bursty": BURSTY, "decode_burst": DECODE_BURST,
         "heavy_tail": HEAVY_TAIL,
         "shared_prefix": SHARED_PREFIX,
         "overload_spike": OVERLOAD_SPIKE, "diurnal": DIURNAL}


def _zipf_cdf(n: int, s: float) -> List[float]:
    w = [1.0 / (k ** s) for k in range(1, n + 1)]
    tot = sum(w)
    acc, cdf = 0.0, []
    for x in w:
        acc += x
        cdf.append(acc / tot)
    return cdf


def sample_tenant(rng: random.Random, cdf: List[float]) -> int:
    """Zipf-popular tenant id: 0 is the hottest."""
    return min(bisect.bisect_left(cdf, rng.random()), len(cdf) - 1)


def _lognormal_params(spec: WorkloadSpec) -> tuple:
    """Pick (mu, sigma) so the clipped lognormal lands near the target mean."""
    mean = spec.mean_len
    sigma = spec.sigma
    mu = math.log(mean) - 0.5 * sigma ** 2
    return mu, sigma


def sample_length(spec: WorkloadSpec, rng: random.Random) -> int:
    mu, sigma = _lognormal_params(spec)
    v = int(rng.lognormvariate(mu, sigma))
    return max(spec.min_len, min(spec.max_len, v))


def sample_output_len(spec: WorkloadSpec, rng: random.Random) -> int:
    # geometric-ish output lengths
    return max(1, int(rng.expovariate(1.0 / spec.out_mean)))


def arrival_times(spec: WorkloadSpec, qps: float, duration: float,
                  rng: random.Random) -> Iterator[float]:
    """Arrival process: plain Poisson, or a two-state Markov-modulated
    Poisson process when burst_factor > 1.  The long-run average rate is
    `qps` in both cases: the peak state runs at burst_factor×qps for
    burst_duty of each period, the quiet state absorbs the remainder.

    Diurnal specs (`diurnal_period` > 0) thin a PEAK-rate (`qps`) Poisson
    stream against a raised sinusoid instead: rate(t) swings between
    qps·diurnal_floor (trough) and qps (crest) once per period."""
    if spec.diurnal_period > 0.0:
        per, fl = spec.diurnal_period, spec.diurnal_floor
        t = 0.0
        while True:
            t += rng.expovariate(qps)
            if t >= duration:
                return
            envelope = fl + (1.0 - fl) * 0.5 * (
                1.0 - math.cos(2.0 * math.pi * t / per))
            if rng.random() < envelope:
                yield t
        return
    if spec.burst_factor <= 1.0:
        t = 0.0
        while True:
            t += rng.expovariate(qps)
            if t >= duration:
                return
            yield t
        return
    duty, period, bf = spec.burst_duty, spec.burst_period, spec.burst_factor
    if bf * duty > 1.0:
        raise ValueError(
            f"burst_factor·burst_duty = {bf * duty:.2f} > 1: the quiet "
            f"state cannot absorb the burst, so the long-run rate would "
            f"exceed qps")
    hi = bf * qps
    lo = qps * (1.0 - duty * bf) / max(1.0 - duty, 1e-9)
    t = 0.0
    while t < duration:
        cycle0 = math.floor(t / period) * period
        # the epsilon guards a float livelock: when duty*period is not
        # exactly representable (e.g. 0.15×4.0), a t clamped to the burst
        # end can still test < the boundary, making seg_end == t — and
        # then no draw ever advances the clock
        in_burst = t < cycle0 + duty * period - 1e-12
        seg_end = cycle0 + (duty * period if in_burst else period)
        rate = hi if in_burst else lo
        if rate <= 0.0:
            t = seg_end
            continue
        t += rng.expovariate(rate)
        if t < seg_end:
            if t >= duration:
                return
            yield t
        else:
            t = seg_end


def generate(
    spec: WorkloadSpec,
    qps: float,
    duration: float,
    seed: int = 0,
    with_tokens: bool = False,
    shared_prefix_prob: float = 0.0,
    vocab: int = 50000,
) -> List[Request]:
    """Arrivals over [0, duration) per the spec's process. Optionally attach
    token ids with shared prefixes (for cache-aware scheduling).

    When `spec.n_tenants` > 0 (the `shared_prefix` scenario) every
    tokenized request opens with its tenant's system prompt — tenant
    picked Zipf(spec.tenant_zipf), so a handful of hot prompts carry most
    of the traffic; `shared_prefix_prob` is ignored in that mode.  A
    sampled length shorter than the prompt truncates it (a prefix of a
    system prompt still shares pages with its siblings)."""
    rng = random.Random(seed)
    reqs: List[Request] = []
    rid = 0
    prefixes = [tuple(rng.randrange(vocab) for _ in range(256))
                for _ in range(4)]
    tenant_cdf, tenant_prompts = None, []
    if spec.n_tenants > 0:
        tenant_cdf = _zipf_cdf(spec.n_tenants, spec.tenant_zipf)
        tenant_prompts = [
            tuple(rng.randrange(vocab)
                  for _ in range(spec.tenant_prefix_len))
            for _ in range(spec.n_tenants)]
    class_names: List[str] = []
    class_cdf: List[float] = []
    if spec.class_mix:
        tot = sum(spec.class_mix.values())
        acc = 0.0
        for name, p in spec.class_mix.items():
            acc += p / tot
            class_names.append(name)
            class_cdf.append(acc)
    for t in arrival_times(spec, qps, duration, rng):
        L = sample_length(spec, rng)
        tokens = None
        if with_tokens:
            if tenant_prompts:
                pre = tenant_prompts[sample_tenant(rng, tenant_cdf)]
                body = tuple(rng.randrange(vocab)
                             for _ in range(max(L - len(pre), 0)))
                tokens = (pre + body)[:L]
            elif rng.random() < shared_prefix_prob:
                pre = prefixes[rng.randrange(len(prefixes))]
                body = tuple(rng.randrange(vocab)
                             for _ in range(max(L - len(pre), 0)))
                tokens = (pre + body)[:L]
            else:
                tokens = tuple(rng.randrange(vocab) for _ in range(L))
        kw = {}
        if class_names:
            i = min(bisect.bisect_left(class_cdf, rng.random()),
                    len(class_names) - 1)
            cls = SLO_CLASSES[class_names[i]]
            kw = dict(priority=cls.priority, slo_e2e=cls.slo_e2e,
                      slo_class=cls.name)
        reqs.append(Request(
            rid=rid, arrival_time=t, input_len=L,
            output_len=sample_output_len(spec, rng), tokens=tokens, **kw))
        rid += 1
    return reqs


def empirical_mean_len(spec: WorkloadSpec, n: int = 20000, seed: int = 1
                       ) -> float:
    rng = random.Random(seed)
    return sum(sample_length(spec, rng) for _ in range(n)) / n
