"""Synthetic workloads matching the paper's §5 evaluation traffic.

- short:  input lengths 0–3K tokens, mean ≈ 1K   (Fig 6a; Chunk 3K)
- long:   input lengths 3K–64K tokens, mean ≈ 6.7K (Fig 6b; Chunk 16K)
- decode: combined in+out ≈ 2.5K tokens, avg batch 35 (Fig 7/8)

Arrivals are Poisson (the M in the paper's M/D/S analysis).
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Iterator, List, Optional

from repro.core.types import Request


@dataclasses.dataclass
class WorkloadSpec:
    name: str
    min_len: int
    max_len: int
    mean_len: float
    out_mean: int = 200
    sigma: float = 0.8            # lognormal shape (tail heaviness)


SHORT = WorkloadSpec("short", 16, 3000, 1000.0)
LONG = WorkloadSpec("long", 3000, 64000, 6700.0)
DECODE = WorkloadSpec("decode", 512, 4096, 2000.0, out_mean=500)

SPECS = {"short": SHORT, "long": LONG, "decode": DECODE}


def _lognormal_params(spec: WorkloadSpec) -> tuple:
    """Pick (mu, sigma) so the clipped lognormal lands near the target mean."""
    mean = spec.mean_len
    sigma = spec.sigma
    mu = math.log(mean) - 0.5 * sigma ** 2
    return mu, sigma


def sample_length(spec: WorkloadSpec, rng: random.Random) -> int:
    mu, sigma = _lognormal_params(spec)
    v = int(rng.lognormvariate(mu, sigma))
    return max(spec.min_len, min(spec.max_len, v))


def sample_output_len(spec: WorkloadSpec, rng: random.Random) -> int:
    # geometric-ish output lengths
    return max(1, int(rng.expovariate(1.0 / spec.out_mean)))


def generate(
    spec: WorkloadSpec,
    qps: float,
    duration: float,
    seed: int = 0,
    with_tokens: bool = False,
    shared_prefix_prob: float = 0.0,
    vocab: int = 50000,
) -> List[Request]:
    """Poisson arrivals over [0, duration). Optionally attach token ids with
    shared prefixes (for cache-aware scheduling experiments)."""
    rng = random.Random(seed)
    reqs: List[Request] = []
    t = 0.0
    rid = 0
    prefixes = [tuple(rng.randrange(vocab) for _ in range(256))
                for _ in range(4)]
    while True:
        t += rng.expovariate(qps)
        if t >= duration:
            break
        L = sample_length(spec, rng)
        tokens = None
        if with_tokens:
            if rng.random() < shared_prefix_prob:
                pre = prefixes[rng.randrange(len(prefixes))]
                body = tuple(rng.randrange(vocab)
                             for _ in range(max(L - len(pre), 0)))
                tokens = (pre + body)[:L]
            else:
                tokens = tuple(rng.randrange(vocab) for _ in range(L))
        reqs.append(Request(
            rid=rid, arrival_time=t, input_len=L,
            output_len=sample_output_len(spec, rng), tokens=tokens))
        rid += 1
    return reqs


def empirical_mean_len(spec: WorkloadSpec, n: int = 20000, seed: int = 1
                       ) -> float:
    rng = random.Random(seed)
    return sum(sample_length(spec, rng) for _ in range(n)) / n
