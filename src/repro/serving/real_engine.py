"""Real JAX engine backends for the EnginePlane contract.

`RealPrefillEngine` / `RealDecodeEngine` plug into `ClusterRuntime`
exactly where `SimPrefillInstance` / `SimDecodeInstance` do — same
scheduler feedback, same DecodeDPState accounting — but every pass/step
is an actual jitted model forward executed on the engine's worker
thread.  The runtime runs in realtime mode (wall clock): `start_pass` /
`start_step` return the ASYNC sentinel, and the worker posts the
matching `pass_end` / `step_end` completion to the runtime's event loop.

Prefill is TRUE chunked prefill with two cache backends:

  dense (default)        each granted (request, tokens) slice extends the
      request's private batch-1 KV cache via `prefill_chunk`; completion
      publishes the whole cache on the `KVHandoffBus`.
  page-native (opt-in)   chunks write DIRECTLY into `BlockPool` pages via
      `paged_prefill_step` — no batch-1 staging cache exists.  With
      `share_prefix`, a `PagePrefixBinder` resolves each new prompt's
      longest cached prefix to live pages at enqueue time, so those
      chunks are never computed (an exact full-prompt hit skips prefill
      entirely and replays the stored first token).  Completion gathers
      only the pages the request holds (`paged_gather_blocks`) into a
      `PageHandoff` — the handoff-realization copy of the dense path is
      gone, and the payload is sized by the prompt, not max_len.

When the prompt completes, the first output token (argmax of the
last-chunk logits) plus the cache/handoff are published on the
`KVHandoffBus` — the paper's P/D KV-cache transfer, priced by
`transfer_time` on the runtime heap and physically realised at join
time.

Decode is CONTINUOUS BATCHED decode with two cache backends behind one
engine:

  padded (block_size=0)  each DP owns a `max_batch`-slot dense cache
      (`init_cache`); a free SLOT is the admission token.
  paged  (block_size>0)  each DP owns a shared `BlockPool` + block-table
      cache (`init_paged_cache`); admission is by free-BLOCK count — a
      request's lifetime pages are reserved at join and returned at
      leave/drain, so the same KV memory budget sustains far more
      concurrent short requests than max_len-padded slots would.

Handed-off requests JOIN by `cache_join`/`paged_cache_join` into a free
slot (a `PageHandoff` joins by `paged_adopt_blocks`: shared prefix pages
already resident on the DP are pointed at, not copied), every step runs
one batched `decode_step`/`paged_decode_step` per occupied DP behind the
instance sync barrier, and finished requests LEAVE by freeing their slot
(paged: also dropping their table row and returning their blocks).  A
decode DP with `share_prefix` publishes each joined prompt's pages into
its own binder and COPY-ON-WRITES the partial tail block EAGERLY at join
— the request's very first decode write would land in the now-shared
block, so the divergence point is known and the copy happens while no
step is in flight.  All scheduler state mutation happens on the runtime
thread (enqueue/finish_pass/start_step/finish_step); worker threads only
execute JAX computations on snapshots — device caches are never mutated
while a pass/step is in flight.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import queue
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.core.types import DispatchCommand, Request, RequestPhase
from repro.models.model import (
    _require_pageable_prefill, cache_join, cache_take, decode_step,
    init_cache, init_paged_cache, mixed_step, paged_adopt_blocks,
    paged_cache_clear_slot, paged_cache_join, paged_cache_take,
    paged_clear_rows, paged_copy_block, paged_decode_step,
    paged_gather_blocks, paged_layout, paged_prefill_step, prefill_chunk,
)
from repro.serving.engine import SimDecodeInstance, SimPrefillInstance
from repro.serving.kv_pool import BlockPool, pad_block_table
from repro.serving.page_share import PagePrefixBinder
from repro.serving.plane import ASYNC, PassResult, StartResult, UnifiedEngine


# ---------------------------------------------------------------------------
# Shared engine spec + KV handoff bus
# ---------------------------------------------------------------------------


class _LockedJit:
    """A jitted callable serialized behind the deployment's mesh lock.

    One mesh is ONE shared device set, and XLA's CPU collective
    rendezvous deadlocks when two multi-device programs interleave their
    per-device participant launches (e.g. the prefill worker's chunk
    all-reduce racing the decode worker's step all-to-all — both wait
    forever for participants the other program's threads are holding).
    So on the sharded plane every program runs exclusively: take the
    lock, launch, block until the result is materialized, release.  This
    is also physically honest — concurrent engines CONTEND for the one
    mesh the way they would for one accelerator.

    `lower()` forwards to the underlying jit so HLO probes
    (`spec.jit_paged_decode.lower(...).compile().as_text()`) still work.
    """

    def __init__(self, fn, lock):
        self._fn, self._lock = fn, lock

    def __call__(self, *args, **kwargs):
        with self._lock:
            out = self._fn(*args, **kwargs)
            jax.block_until_ready(out)
            return out

    def lower(self, *args, **kwargs):
        return self._fn.lower(*args, **kwargs)


@dataclasses.dataclass
class EngineSpec:
    """Model + jit context shared by every engine of one deployment, so
    each (chunk-shape, batch-shape) compiles exactly once per process
    instead of once per engine instance.

    `max_batch` doubles as the decode-plane MEMORY budget: the padded
    plane allocates max_batch slots of max_len tokens per DP; the paged
    plane (block_size > 0) spends the SAME token budget on a shared
    `BlockPool` of max_batch·max_len/block_size blocks, with
    `decode_slots` (default 2×max_batch) cheap batch rows on top — so a
    paged DP admits by free-block count and sustains more concurrent
    requests than the padded DP at equal memory.

    With a `mesh` (sharded plane, paged only) the spec becomes
    MESH-NATIVE: params are device_put with `distributed.sharding`
    pspecs, every paged step jit is wrapped in
    `annotate.activate(mesh, axis_map, ep_shard_map=True)` so MoE layers
    take the explicit all-to-all EP path of `models.moe_ep`, and output
    caches are pinned to `paged_cache_pspecs` layouts.  A decode
    instance then merges its DP units' rows into ONE cache sharded over
    the mesh's "data" axis — each step is a genuine cross-DP
    synchronized program, which is where the paper's sync barrier
    physically lives on this plane."""
    cfg: ModelConfig
    params: Any
    max_len: int = 256
    max_batch: int = 8          # decode slots per DP unit (= memory budget)
    max_new: int = 0            # 0 = no cap on generated tokens
    block_size: int = 0         # paged KV block size (0 = padded slots)
    decode_slots: int = 0       # paged batch rows per DP (0 = 2×max_batch)
    pool_blocks: int = 0        # physical blocks per DP (0 = equal-memory)
    prefill_slots: int = 0      # page-native prefill rows (0 = auto)
    prefill_pool_blocks: int = 0  # page-native prefill pool (0 = auto)
    mesh: Any = None            # jax.sharding.Mesh -> sharded engines
    parallel: Any = None        # ParallelConfig (None = EP over the mesh)

    def __post_init__(self):
        cfg = self.cfg
        self.jit_prefill_chunk = jax.jit(
            lambda p, t, c: prefill_chunk(cfg, p, t, c))
        self.jit_decode = jax.jit(
            lambda p, t, c: decode_step(cfg, p, t, c))
        self.jit_join = jax.jit(cache_join)
        if self.block_size:
            self.nbt, _ = paged_layout(cfg, self.max_len, self.block_size)
            self.jit_paged_decode = jax.jit(
                lambda p, t, c: paged_decode_step(cfg, p, t, c))
            self.jit_paged_join = jax.jit(
                lambda d, s, slot, tab: paged_cache_join(cfg, d, s, slot,
                                                         tab))
            # page-native prefill + block-granular handoff (one jitted
            # shape each: tables/masks are padded to nbt width, slot and
            # block ids are traced scalars)
            self.jit_paged_prefill = jax.jit(
                lambda p, t, c, slot: paged_prefill_step(cfg, p, t, c, slot))
            self.jit_gather_blocks = jax.jit(
                lambda c, ids: paged_gather_blocks(cfg, c, ids))
            self.jit_adopt_blocks = jax.jit(
                lambda d, pay, slot, tab, cm, km, cur: paged_adopt_blocks(
                    cfg, d, pay, slot, tab, cm, km, cur))
            self.jit_copy_block = jax.jit(
                lambda c, src, dst: paged_copy_block(cfg, c, src, dst))
            self.jit_clear_rows = jax.jit(paged_clear_rows)
            # unified mixed-batch step (decode rows + piggybacked prefill
            # chunks in one XLA program): retraces per (n_chunks, chunk
            # lengths) combination — slots and masks are traced
            self.jit_mixed = jax.jit(
                lambda p, t, c, chunks, mask: mixed_step(cfg, p, t, c,
                                                         chunks, mask))
        self.n_dp = 1
        self.axis_map = None
        self._mesh_lock = threading.RLock()
        if self.mesh is not None:
            self._init_sharded()

    def _init_sharded(self) -> None:
        """Turn the paged step jits into MESH programs.

        Parameters are committed once with `param_pspecs` layouts; each
        step fn is re-wrapped so (a) `annotate.activate(..,
        ep_shard_map=True)` is live at trace time — MoE layers route
        through `moe_block_ep`'s explicit all-to-all whenever the token
        count divides the device count — and (b) the output cache is
        pinned to its `paged_cache_pspecs` layout, computed from the
        TRACED shapes so the same wrapper serves the merged decode
        cache, the prefill cache, and any dry-run geometry."""
        if not self.block_size:
            raise ValueError(
                "sharded engines are paged-only (set block_size > 0)")
        import numpy as np
        from repro.config.base import ParallelConfig
        from repro.distributed import annotate
        from repro.distributed.sharding import (
            data_axes_of, named, paged_cache_pspecs, param_pspecs)
        cfg, mesh = self.cfg, self.mesh
        if self.parallel is None:
            # EP over the WHOLE mesh when the expert count divides it
            # (launch/dryrun's default_parallel rule) — on a data×1
            # engine mesh this is what makes every decode step carry a
            # cross-DP all-to-all
            par = ParallelConfig()
            mc = getattr(cfg, "moe", None)
            E = mc.num_experts if mc is not None else 0
            for cand in (("data", "model"), ("model",)):
                n = int(np.prod([dict(mesh.shape).get(a, 1) for a in cand]))
                if E and E % n == 0:
                    par = dataclasses.replace(par, expert_axes=cand)
                    break
            self.parallel = par
        par = self.parallel
        self.n_dp = int(dict(mesh.shape)["data"])
        model_size = int(dict(mesh.shape).get(par.model_axis, 1))
        heads_ok = cfg.num_heads == 0 or cfg.num_heads % model_size == 0
        self.axis_map = {
            "tokens": data_axes_of(mesh, par),
            "experts": tuple(a for a in par.expert_axes
                             if a in mesh.axis_names),
            "model": par.model_axis,
            "attn_seq": None if heads_ok else par.model_axis,
        }
        self.params = jax.device_put(
            self.params, named(mesh, param_pspecs(cfg, mesh, par,
                                                  self.params)))

        def sharded(fn):
            def wrapped(p, t, c, *rest):
                with annotate.activate(mesh, self.axis_map,
                                       ep_shard_map=True):
                    out = fn(p, t, c, *rest)
                cspec = named(mesh, paged_cache_pspecs(cfg, mesh, par,
                                                       out[-1]))
                return out[:-1] + (
                    jax.lax.with_sharding_constraint(out[-1], cspec),)
            return jax.jit(wrapped)

        self.jit_paged_decode = sharded(
            lambda p, t, c: paged_decode_step(cfg, p, t, c))
        self.jit_paged_prefill = sharded(
            lambda p, t, c, slot: paged_prefill_step(cfg, p, t, c, slot))
        self.jit_mixed = sharded(
            lambda p, t, c, chunks, mask: mixed_step(cfg, p, t, c,
                                                     chunks, mask))
        # EVERY jit becomes a mesh program once params are sharded (the
        # dense-path prefill chunk carries an all-reduce over the
        # data-sharded expert weights, joins/gathers reshard sharded
        # caches) — funnel them all through the mesh lock so no two
        # multi-device programs ever interleave (see _LockedJit)
        for name in ("jit_prefill_chunk", "jit_decode", "jit_join",
                     "jit_paged_decode", "jit_paged_join",
                     "jit_paged_prefill", "jit_gather_blocks",
                     "jit_adopt_blocks", "jit_copy_block",
                     "jit_clear_rows", "jit_mixed"):
            setattr(self, name,
                    _LockedJit(getattr(self, name), self._mesh_lock))

    @property
    def sharded(self) -> bool:
        return self.mesh is not None

    def device_lock(self):
        """The deployment's mesh lock (a no-op context when unsharded).
        Engine code holding it may nest jitted calls freely (RLock)."""
        if self.mesh is None:
            return contextlib.nullcontext()
        return self._mesh_lock

    def run_eager(self, fn, *args):
        """Run an EAGER (unjitted) op over sharded arrays under the mesh
        lock, blocking until the result is materialized — eager dispatch
        is async too, so without the barrier its device program could
        still be in flight when the next step's collectives launch.
        Plain passthrough on unsharded specs."""
        if self.mesh is None:
            return fn(*args)
        with self._mesh_lock:
            out = fn(*args)
            jax.block_until_ready(out)
        return out

    def shard_cache(self, cache: Dict) -> Dict:
        """Commit a freshly built paged cache to its mesh layout (no-op
        for unsharded specs)."""
        if self.mesh is None:
            return cache
        from repro.distributed.sharding import named, paged_cache_pspecs
        return jax.device_put(cache, named(self.mesh, paged_cache_pspecs(
            self.cfg, self.mesh, self.parallel, cache)))

    def merged_paged_cache(self) -> Dict:
        """ONE instance-wide decode cache holding every DP unit's rows
        (sharded plane): slot s belongs to DP s // paged_slots, physical
        block b to DP b // paged_pool_blocks — matching the per-DP
        `BlockPool(base=...)` allocators — so both pool dims shard over
        the mesh's data axis and DP d's rows live on mesh rank d."""
        n = self.n_dp
        return self.shard_cache(init_paged_cache(
            self.cfg, n * self.paged_slots, n * self.paged_pool_blocks,
            self.max_len, self.block_size))

    @property
    def paged(self) -> bool:
        return self.block_size > 0

    @property
    def paged_slots(self) -> int:
        from repro.config.base import PAGED_SLOTS_FACTOR
        return self.decode_slots or self.max_batch * PAGED_SLOTS_FACTOR

    @property
    def paged_pool_blocks(self) -> int:
        """Physical blocks per DP; default matches the padded plane's
        token capacity exactly (+1 for the reserved null block)."""
        if self.pool_blocks:
            return self.pool_blocks
        return self.max_batch * self.max_len // self.block_size + 1

    @property
    def prefix_sharable(self) -> bool:
        """Page sharing needs every cached layer to live in pool pages —
        attention-only decoder-only configs (SSM/encoder state has no
        page representation)."""
        if not self.paged:
            return False
        try:
            _require_pageable_prefill(self.cfg)
        except ValueError:
            return False
        return True

    @property
    def paged_prefill_slots(self) -> int:
        """Concurrent in-flight prompts per page-native prefill engine."""
        return self.prefill_slots or max(8, 2 * self.max_batch)

    @property
    def paged_prefill_blocks(self) -> int:
        """Prefill-pool size: 2× the slot working set, so completed pages
        can stay resident in the prefix cache while fresh prompts stage."""
        if self.prefill_pool_blocks:
            return self.prefill_pool_blocks
        per_slot = self.max_len // self.block_size
        return 2 * self.paged_prefill_slots * per_slot + 1

    def request_cache(self) -> Dict:
        return init_cache(self.cfg, 1, self.max_len)

    def batch_cache(self) -> Dict:
        return init_cache(self.cfg, self.max_batch, self.max_len)

    def paged_cache(self) -> Dict:
        return self.shard_cache(init_paged_cache(
            self.cfg, self.paged_slots, self.paged_pool_blocks,
            self.max_len, self.block_size))

    def prefill_paged_cache(self) -> Dict:
        return self.shard_cache(init_paged_cache(
            self.cfg, self.paged_prefill_slots, self.paged_prefill_blocks,
            self.max_len, self.block_size))

    def target_len(self, req: Request) -> int:
        if self.max_new:
            return min(req.output_len, self.max_new)
        return req.output_len

    def lifetime_tokens(self, req: Request) -> int:
        """KV tokens resident when `req` finishes: the prompt plus one
        written KV entry per decode step (the final sampled token never
        enters the cache)."""
        return req.input_len + max(self.target_len(req) - 1, 0)


@dataclasses.dataclass
class PageHandoff:
    """Block-granular prefill→decode KV payload (`paged_gather_blocks`
    output, nbt-padded): only the pages the prompt actually occupies
    travel, not a max_len dense cache.  `n_tokens` is the prompt length
    the payload covers (payload row i holds tokens [i·bs, (i+1)·bs))."""
    payload: Dict
    n_tokens: int


@dataclasses.dataclass
class GenState:
    """Per-request generation context carried across the P/D handoff.
    `cache` is a dense batch-1 cache (dense prefill / drain re-park) or a
    `PageHandoff` (page-native prefill); None while resident."""
    rid: int
    cache: Optional[Any]        # parked KV payload (None while resident)
    tokens: List[int]


class KVHandoffBus:
    """Prefill → decode KV-cache handoff registry (one per deployment).

    The prefill plane publishes a finished request's cache + first token;
    the decode plane takes the cache at join time.  A drained (watchdog)
    decode instance re-parks its residents' caches here so re-dispatch
    lands them on a healthy instance with generation state intact.  All
    access happens on the runtime thread."""

    def __init__(self):
        self._gens: Dict[int, GenState] = {}

    def publish(self, rid: int, cache: Any, first_token: int) -> GenState:
        gen = GenState(rid=rid, cache=cache, tokens=[first_token])
        self._gens[rid] = gen
        return gen

    def gen(self, rid: int) -> GenState:
        return self._gens[rid]

    def get(self, rid: int) -> Optional[GenState]:
        return self._gens.get(rid)


class _Worker(threading.Thread):
    """One serial job executor per engine (the engine's 'device')."""

    def __init__(self, name: str):
        super().__init__(daemon=True, name=name)
        self.jobs: "queue.Queue[Optional[Any]]" = queue.Queue()

    def submit(self, job) -> None:
        self.jobs.put(job)

    def stop(self) -> None:
        self.jobs.put(None)

    def run(self) -> None:
        while True:
            job = self.jobs.get()
            if job is None:
                return
            job()


class _WorkerOwner:
    """start/stop lifecycle shared by the real engines.  Each start()
    spawns a fresh worker thread, so a server can serve() repeatedly
    after a COMPLETED run (after a timeout the deployment may hold
    in-flight passes and is not reusable).  A worker-thread exception is
    parked in `_error` and re-raised on the runtime thread by the next
    start/finish call, so a failed forward surfaces immediately instead
    of blocking the loop until its horizon."""

    def __init__(self, tag: str):
        self._tag = tag
        self._worker: Optional[_Worker] = None
        self._error: Optional[BaseException] = None

    def start(self) -> None:
        self._worker = _Worker(self._tag)
        self._worker.start()

    def stop(self) -> None:
        if self._worker is not None:
            self._worker.stop()

    def join_worker(self, timeout: float = 10.0) -> None:
        if self._worker is not None:
            self._worker.join(timeout=timeout)
            self._worker = None

    def _raise_worker_error(self) -> None:
        if self._error is not None:
            err, self._error = self._error, None
            raise err


# ---------------------------------------------------------------------------
# Real prefill
# ---------------------------------------------------------------------------


class _PrefillCtx:
    """Model-side state of one in-flight prefill (batch-1 chunked cache)."""

    def __init__(self, spec: EngineSpec):
        self.cache = spec.request_cache()
        self.consumed = 0
        self.first_token: Optional[int] = None


class _PagedPrefillCtx:
    """Model-side state of one PAGE-NATIVE prefill: a row of the
    engine-shared paged cache plus the request's block table.  `slot` is
    None until the engine stages the request (allocates its fresh blocks
    and installs its table row); a full-prefix cache hit never stages —
    `consumed` starts at `input_len` and the stored first token replays."""

    def __init__(self, table: List[int], claimed: int,
                 first_token: Optional[int] = None):
        self.slot: Optional[int] = None
        self.table = table          # physical blocks (claimed prefix first)
        self.claimed = claimed      # prefix tokens resolved from cache
        self.consumed = claimed     # prompt tokens whose KV is in pages
        self.first_token = first_token


class RealPrefillEngine(SimPrefillInstance, _WorkerOwner):
    """Chunked-prefill engine: scheduler-side queueing/batch-forming and
    EndForward bookkeeping are inherited from the simulated instance —
    only the pass execution differs (jitted `prefill_chunk` on the worker
    thread instead of a cost-model duration).

    With `page_native=True` chunks write straight into `BlockPool` pages
    (`paged_prefill_step`), and `share_prefix=True` adds a
    `PagePrefixBinder`: at enqueue time a new prompt's longest cached
    prefix is CLAIMED (refcounted pages, no copy) so its chunks are never
    computed.  The claim must equal the hit the scheduler credited — both
    sides resolve against the same binder in the same runtime-thread tick
    (see `page_share.EngineBackedPrefixIndex`), so a mismatch is a wiring
    bug and raises."""

    def __init__(self, instance_id: int, dp_ids: Sequence[int], chunk: int,
                 spec: EngineSpec, bus: KVHandoffBus,
                 page_native: bool = False, share_prefix: bool = False,
                 cache_budget_tokens: Optional[int] = None):
        super().__init__(instance_id, dp_ids, chunk, cost=None)
        _WorkerOwner.__init__(self, f"prefill-{instance_id}")
        self.spec = spec
        self.bus = bus
        self._post = None
        self._ctx: Dict[int, _PrefillCtx] = {}
        self.page_native = bool(page_native)
        self.binder: Optional[PagePrefixBinder] = None
        if self.page_native:
            if not spec.prefix_sharable:
                raise ValueError(
                    "page_native prefill needs block_size > 0 and an "
                    "attention-only decoder-only config")
            self.pool = BlockPool(spec.paged_prefill_blocks, spec.block_size)
            self.cache = spec.prefill_paged_cache()
            if share_prefix:
                self.binder = PagePrefixBinder(
                    self.pool, budget_tokens=cache_budget_tokens)
            self._free_slots: List[int] = list(
                range(spec.paged_prefill_slots))
            self._pctx: Dict[int, _PagedPrefillCtx] = {}
        elif share_prefix:
            raise ValueError("share_prefix requires page_native=True")
        # page-native stats (read after the run; only the worker writes
        # chunks_run, only the runtime thread writes the claim counters)
        self.chunks_run = 0
        self.full_hits = 0

    # -- lifecycle -------------------------------------------------------
    def bind_loop(self, loop) -> None:
        self._post = loop.post

    # -- EnginePlane -----------------------------------------------------
    def enqueue(self, cmd: DispatchCommand, now: float) -> None:
        if self.page_native:
            for dp_id, lst in cmd.assignments.items():
                for req, tok in lst:
                    if req.rid not in self._pctx:
                        self._claim_prefix(req, tok)
        super().enqueue(cmd, now)

    def _claim_prefix(self, req: Request, tok: int) -> None:
        """First sight of a request: resolve its cached prefix to pages.
        The scheduler already credited `expected` hit tokens (it granted
        `tok` now and debited `remaining_prefill` by grant + hit), so the
        engine-side claim must match exactly — the claimed chunks will
        never be granted again."""
        expected = req.input_len - req.remaining_prefill - tok
        toks = (req.tokens or ())[:req.input_len]
        if self.binder is not None and toks:
            claim, blocks, first = self.binder.claim(toks)
            self.binder.record(claim, req.input_len)
        else:
            claim, blocks, first = 0, [], None
        if claim != expected:
            raise RuntimeError(
                f"request {req.rid}: scheduler credited a {expected}-token "
                f"prefix hit but the engine binder resolved {claim} — "
                f"cache-aware dispatch on the real plane must match "
                f"through EngineBackedPrefixIndex")
        if claim >= req.input_len:
            self.full_hits += 1
        self._pctx[req.rid] = _PagedPrefillCtx(list(blocks), claim, first)

    def _stage(self, req: Request, ctx: _PagedPrefillCtx) -> bool:
        """Give an unstaged request a cache row + its fresh blocks, and
        install its table/cursor device-side.  Runs on the runtime thread
        with no pass in flight, so the cache mutation cannot race the
        worker.  Returns False (leaving ctx untouched) under slot/page
        exhaustion — the caller requeues the request's chunks."""
        if not self._free_slots:
            return False
        need = self.pool.blocks_for(req.input_len) - len(ctx.table)
        if need > self.pool.free_count and self.binder is not None:
            self.binder.ensure_free(need)
        if need > self.pool.free_count:
            return False
        fresh = self.pool.alloc(need)
        ctx.table = ctx.table + fresh
        ctx.slot = self._free_slots.pop()
        nbt = self.spec.nbt
        if fresh:
            # reused pages keep their previous tenant's kv_pos; any stale
            # pos <= the reader's cursor would alias as valid history
            ids = jnp.asarray(pad_block_table(fresh, nbt), jnp.int32)
            self.cache = self.spec.jit_clear_rows(self.cache, ids)
        tab = jnp.asarray(pad_block_table(ctx.table, nbt), jnp.int32)
        self.cache = self.spec.run_eager(
            lambda c: dict(c, block_tab=c["block_tab"].at[ctx.slot].set(tab),
                           cur=c["cur"].at[ctx.slot].set(ctx.claimed)),
            self.cache)
        return True

    def start_pass(self, now: float) -> StartResult:
        self._raise_worker_error()
        if self.page_native and not self.busy:
            # stage before batch-forming, in queue order, so _begin_pass
            # only hands the worker requests with a live cache row
            staged = set()
            for d in self.dp_ids:
                for req, _tok in self.queues[d]:
                    ctx = self._pctx.get(req.rid)
                    if (ctx is None or ctx.slot is not None
                            or ctx.consumed >= req.input_len
                            or req.rid in staged):
                        continue
                    if not self._stage(req, ctx):
                        break       # exhausted: later arrivals wait too
                    staged.add(req.rid)
        batch = self._begin_pass(now)
        if batch is None:
            return None
        if self.page_native:
            batch = self._strip_unstaged(batch)
            if batch is None:
                return None
        post = self._post        # bound per run: an abandoned job cannot
        self._worker.submit(     # post into a later run's loop
            lambda: self._exec_pass(batch, post))
        return ASYNC

    def _strip_unstaged(self, batch: Dict[int, List[Tuple[Request, int]]]
                        ) -> Optional[Dict[int, List[Tuple[Request, int]]]]:
        """Drop batch items whose request has no cache row (page/slot
        exhaustion) and requeue them at the FRONT of their queue; roll
        the pass back entirely if nothing runnable remains.  Full-hit
        requests (consumed == input_len, slot None) always stay — their
        zero-token markers complete without touching the device."""
        kept: Dict[int, List[Tuple[Request, int]]] = {}
        dropped = 0
        for d, taken in batch.items():
            keep: List[Tuple[Request, int]] = []
            back: List[Tuple[Request, int]] = []
            for req, tok in taken:
                ctx = self._pctx[req.rid]
                if ctx.slot is None and ctx.consumed < req.input_len:
                    back.append((req, tok))
                else:
                    keep.append((req, tok))
            if keep:
                kept[d] = keep
            for item in reversed(back):
                self.queues[d].appendleft(item)
            dropped += len(back)
        if not kept:
            # nothing runnable: undo _begin_pass bookkeeping and idle
            self._current = None
            self.busy = False
            self.passes -= 1
            self.capacity_offered -= len(self.dp_ids) * self.chunk
            return None
        if dropped:
            self._current = kept
        return kept

    def _exec_pass(self, batch: Dict[int, List[Tuple[Request, int]]],
                   post) -> None:
        # worker thread: pure model execution on engine-private contexts
        try:
            for taken in batch.values():
                for req, tok in taken:
                    self._run_chunk(req, tok)
        except BaseException as e:      # surface on the runtime thread
            self._error = e
        post("pass_end", self)

    def _run_chunk(self, req: Request, tok: int) -> None:
        if self.page_native:
            self._run_chunk_paged(req, tok)
            return
        ctx = self._ctx.get(req.rid)
        if ctx is None:
            ctx = self._ctx[req.rid] = _PrefillCtx(self.spec)
        ids = (req.tokens or ())[ctx.consumed: ctx.consumed + tok]
        if ids:
            arr = jnp.asarray([ids], jnp.int32)
            with self.spec.device_lock():
                logits, ctx.cache = self.spec.jit_prefill_chunk(
                    self.spec.params, arr, ctx.cache)
                ctx.consumed += len(ids)
                if ctx.consumed >= req.input_len and ctx.first_token is None:
                    ctx.first_token = int(jnp.argmax(logits[0]))

    def _run_chunk_paged(self, req: Request, tok: int) -> None:
        # worker thread: extend the request's cache row in place; the
        # engine-shared cache is only rebound here and in the (mutually
        # exclusive) staging path on the runtime thread
        ctx = self._pctx[req.rid]
        ids = (req.tokens or ())[ctx.consumed: ctx.consumed + tok]
        if not ids:
            return
        arr = jnp.asarray([ids], jnp.int32)
        with self.spec.device_lock():
            logits, self.cache = self.spec.jit_paged_prefill(
                self.spec.params, arr, self.cache, ctx.slot)
            self.chunks_run += 1
            ctx.consumed += len(ids)
            if ctx.consumed >= req.input_len and ctx.first_token is None:
                ctx.first_token = int(jnp.argmax(logits[0]))

    def finish_pass(self, now: float) -> PassResult:
        self._raise_worker_error()
        res = super().finish_pass(now)
        for req in res.completed:
            if self.page_native:
                self._complete_paged(req)
                continue
            ctx = self._ctx.pop(req.rid, None)
            if ctx is None or ctx.first_token is None:
                raise RuntimeError(
                    f"request {req.rid} completed prefill without model "
                    f"state (tokens shorter than input_len?)")
            # the paper's KV transfer: park cache + first token on the bus;
            # the first output token is the argmax of the last-chunk logits
            self.bus.publish(req.rid, ctx.cache, ctx.first_token)
            req.generated = 1
        return res

    def _complete_paged(self, req: Request) -> None:
        """Page-native completion: gather ONLY the prompt's pages as the
        handoff payload, publish the pages into the prefix cache, then
        release the engine's row and references.  Ordering matters: the
        gather snapshots page contents before any free; `binder.insert`
        increfs newly bound pages before the engine's own references are
        dropped, so published pages never transit refcount 0."""
        ctx = self._pctx.pop(req.rid, None)
        if ctx is None or ctx.first_token is None:
            raise RuntimeError(
                f"request {req.rid} completed prefill without model "
                f"state (tokens shorter than input_len?)")
        ids = jnp.asarray(pad_block_table(ctx.table, self.spec.nbt),
                          jnp.int32)
        payload = self.spec.jit_gather_blocks(self.cache, ids)
        self.bus.publish(req.rid, PageHandoff(payload, req.input_len),
                         ctx.first_token)
        req.generated = 1
        if self.binder is not None and req.tokens:
            # a prompt's pages are frozen from here on (prefill never
            # writes past input_len), so the partial tail is publishable
            # together with its first-token payload
            self.binder.insert(req.tokens[:req.input_len], ctx.table,
                               first_token=ctx.first_token)
        if ctx.slot is not None:
            self.cache = self.spec.run_eager(
                paged_cache_clear_slot, self.cache, ctx.slot)
            self._free_slots.append(ctx.slot)
        self.pool.free(ctx.table)


# ---------------------------------------------------------------------------
# Real decode
# ---------------------------------------------------------------------------


class _DPDecodeState:
    """One DP unit's padded continuous batch (lazily allocated)."""

    def __init__(self, spec: EngineSpec, n_slots: Optional[int] = None):
        self.spec = spec
        self.cache: Optional[Dict] = None
        n = n_slots if n_slots is not None else spec.max_batch
        self.slots: List[Optional[Request]] = [None] * n
        self.next_tok: List[int] = [0] * n

    def free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slots):
            if r is None:
                return i
        return None

    def occupied(self) -> bool:
        return any(r is not None for r in self.slots)

    # padded plane: a free slot IS the admission token
    def can_admit(self, need_tokens: int, extra_blocks: int = 0,
                  shared_blocks: int = 0) -> bool:
        return self.free_slot() is not None


class _DPPagedState(_DPDecodeState):
    """One DP unit's paged continuous batch: `paged_slots` cheap batch
    rows over a shared `BlockPool`.  Admission is by free-BLOCK count —
    a request's lifetime blocks are reserved at join (so a resident
    request can never strand mid-generation waiting for a page) and
    returned at leave/drain.

    With `share_prefix`, the unit also owns a `PagePrefixBinder`: joined
    prompts publish their pages, and later prompts with a matching prefix
    point at the resident pages instead of re-copying their handoff
    payload rows.  Pool pressure evicts cache entries before refusing an
    admission (`binder.ensure_free`)."""

    def __init__(self, spec: EngineSpec, share_prefix: bool = False):
        super().__init__(spec, n_slots=spec.paged_slots)
        self.pool = BlockPool(spec.paged_pool_blocks, spec.block_size)
        self.held: Dict[int, List[int]] = {}       # rid -> block ids
        self.binder: Optional[PagePrefixBinder] = (
            PagePrefixBinder(self.pool) if share_prefix else None)

    def can_admit(self, need_tokens: int, extra_blocks: int = 0,
                  shared_blocks: int = 0) -> bool:
        need = self.pool.blocks_for(need_tokens)
        if need > self.pool.num_blocks - 1:
            raise ValueError(
                f"request needs {need} blocks, pool holds only "
                f"{self.pool.num_blocks - 1} — raise max_len/pool_blocks")
        # `shared_blocks` prefix pages are already claimed (refs held):
        # they will be pointed at, never allocated, so only the remainder
        # must come out of the free store
        need -= shared_blocks
        if self.binder is not None:
            self.binder.ensure_free(need + extra_blocks)
        return (self.free_slot() is not None
                and need + extra_blocks <= self.pool.free_count)


class _ShardedGroup:
    """Instance-wide device state of the SHARDED decode plane: ONE merged
    paged cache (slot s ↔ DP s // paged_slots, physical block b ↔ DP
    b // paged_pool_blocks) whose pool dims are sharded over the mesh's
    data axis.  A step is a single cross-DP jitted program — the paper's
    DP sync barrier is the program's own collectives (the EP all-to-all
    and the data-axis layout transfers), not the worker's serial per-DP
    job loop the single-device plane approximates it with."""

    def __init__(self, spec: EngineSpec):
        n = spec.n_dp
        self.cache: Dict = spec.merged_paged_cache()
        self.slots: List[Optional[Request]] = [None] * (n * spec.paged_slots)
        self.next_tok: List[int] = [0] * (n * spec.paged_slots)


class _DPShardedState(_DPPagedState):
    """One DP unit's VIEW of the merged sharded cache.  Admission control
    stays strictly per-DP — the `BlockPool` hands out GLOBAL block ids
    from this DP's base-offset range, `free_slot` scans this DP's global
    slot range, the optional prefix binder is private — while `cache`
    reads/writes through to the shared group, so every jitted join /
    clear / step mutation lands in the one merged device cache and the
    inherited `_apply_joins`/`finish_step` machinery works unchanged."""

    def __init__(self, spec: EngineSpec, group: _ShardedGroup, k: int,
                 share_prefix: bool = False):
        self.spec = spec
        self.group = group
        S = spec.paged_slots
        self.lo, self.hi = k * S, (k + 1) * S
        self.slots = group.slots            # SHARED global slot list
        self.next_tok = group.next_tok      # SHARED global feed tokens
        self.pool = BlockPool(spec.paged_pool_blocks, spec.block_size,
                              base=k * spec.paged_pool_blocks)
        self.held: Dict[int, List[int]] = {}
        self.binder: Optional[PagePrefixBinder] = (
            PagePrefixBinder(self.pool) if share_prefix else None)

    @property
    def cache(self) -> Dict:
        return self.group.cache

    @cache.setter
    def cache(self, value: Dict) -> None:
        self.group.cache = value

    def free_slot(self) -> Optional[int]:
        for i in range(self.lo, self.hi):
            if self.slots[i] is None:
                return i
        return None

    def occupied(self) -> bool:
        return any(r is not None for r in self.slots[self.lo:self.hi])


class RealDecodeEngine(SimDecodeInstance, _WorkerOwner):
    """Continuous batched decode: join-on-handoff / leave-on-finish per
    step.  Request/DPState bookkeeping (token counts, first-token stamps,
    KV accounting, drain/epoch) is inherited from the simulated instance;
    this class adds the physical batch caches and the jitted step."""

    def __init__(self, instance_id: int, dp_ids: Sequence[int],
                 spec: EngineSpec, bus: KVHandoffBus,
                 share_prefix: bool = False):
        super().__init__(instance_id, dp_ids, cost=None)
        _WorkerOwner.__init__(self, f"decode-{instance_id}")
        self.spec = spec
        self.bus = bus
        self._post = None
        if share_prefix and not spec.prefix_sharable:
            raise ValueError(
                "share_prefix requires a paged attention-only config")
        if spec.sharded:
            if len(dp_ids) != spec.n_dp:
                raise ValueError(
                    f"a sharded decode instance must own exactly the "
                    f"mesh's data axis: {len(dp_ids)} dp_ids vs "
                    f"data={spec.n_dp}")
            self._group = _ShardedGroup(spec)
            self._dp: Dict[int, _DPDecodeState] = {
                d: _DPShardedState(spec, self._group, k,
                                   share_prefix=share_prefix)
                for k, d in enumerate(dp_ids)}
        elif spec.paged:
            self._dp = {
                d: _DPPagedState(spec, share_prefix=share_prefix)
                for d in dp_ids}
        else:
            self._dp = {d: _DPDecodeState(spec) for d in dp_ids}
        self._pending: List[Tuple[int, Request]] = []
        self._deferred: set = set()   # rids whose join failed can_admit
        self._slot_of: Dict[int, Tuple[int, int]] = {}   # rid -> (dp, slot)
        self._participants: Dict[int, List[Tuple[Request, int]]] = {}
        self._result: Optional[Dict[int, Tuple[Dict, List[int]]]] = None
        self._join_finished: List[Request] = []
        self.peak_resident = 0      # max concurrent resident requests
        self.cow_copies = 0         # eager tail copy-on-writes at join
        self.blocks_shared = 0      # payload rows skipped via shared pages
        # per-step occupancy samples (worker appends, read after the run):
        # (wall seconds, active decode rows, cache rows stepped) — the
        # sharded bench derives sync-stall = Σ dur·(1 − active/rows) from
        # these, i.e. time the cross-DP program spent advancing idle rows
        self.step_samples: List[Tuple[float, int, int]] = []
        self._step_active = 0
        self._step_rows = 0

    # -- lifecycle -------------------------------------------------------
    def bind_loop(self, loop) -> None:
        self._post = loop.post

    # -- EnginePlane -----------------------------------------------------
    def free_kv_tokens(self, dp_id: int,
                       tokens: Optional[Sequence[int]] = None
                       ) -> Optional[int]:
        st = self._dp[dp_id]
        if self.spec.paged:
            free = st.pool.free_count * self.spec.block_size
            if tokens and getattr(st, "binder", None) is not None:
                # credit the claimable block-aligned prefix already
                # resident in this DP's binder: those pages will be
                # POINTED AT, not allocated, so they are headroom for
                # this prompt even though the pool holds them (the same
                # credit EngineBackedPrefixIndex grants at dispatch)
                claim, _full = st.binder.peek(tokens)
                free += st.pool.blocks_for(claim) * self.spec.block_size
            return free
        free_slots = sum(1 for r in st.slots if r is None)
        return free_slots * self.spec.max_len

    def admit(self, dp_id: int, req: Request) -> None:
        # buffered: joins are applied between steps (start_step), never
        # while a worker-thread step is in flight
        self._pending.append((dp_id, req))

    def pending_waits(self) -> List[Request]:
        """Joins deferred by device-side capacity: admitted by the
        scheduler, but can_admit has refused them at least once.  This is
        the real plane's overload signal — the preemption driver swaps
        lower-priority residents out to let these in."""
        return [r for _, r in self._pending if r.rid in self._deferred]

    def preempt(self, rid: int) -> Optional[Request]:
        """Page-level preemption: the drain() mechanics at request
        granularity.  Parks the victim's KV (+ generation state, already
        on the bus) as a dense batch-1 cache, clears its slot/table row,
        returns its pages to the pool.  Re-admission goes through the
        normal join path — a re-parked cache is NOT a PageHandoff, so it
        re-joins via the dense-paged branch with its generated KV intact.
        Refused (None) while a worker step is in flight."""
        if self.busy:
            return None
        loc = self._slot_of.get(rid)
        if loc is None:
            return None
        dp_id, slot = loc
        req = next((r for r in self.running[dp_id] if r.rid == rid), None)
        if req is None:
            return None
        st = self._dp[dp_id]
        if self.spec.paged:
            # eager (unjitted), like drain(): swaps are rare and per-slot
            # jit specialisation would compile mid-overload
            self.bus.gen(rid).cache = self.spec.run_eager(
                paged_cache_take, self.spec.cfg, st.cache, slot)
            st.cache = self.spec.run_eager(
                paged_cache_clear_slot, st.cache, slot)
            st.pool.free(st.held.pop(rid))
        else:
            self.bus.gen(rid).cache = cache_take(st.cache, slot)
        st.slots[slot] = None
        del self._slot_of[rid]
        self.running[dp_id].remove(req)
        return req

    def has_work(self) -> bool:
        return bool(self._pending) or super().has_work()

    def _target_len(self, req: Request) -> int:
        return self.spec.target_len(req)

    def _apply_joins(self, now: float, dp_states) -> None:
        by_id = {s.dp_id: s for s in dp_states}
        still: List[Tuple[int, Request]] = []
        for dp_id, req in self._pending:
            st = self._dp[dp_id]
            gen = self.bus.gen(req.rid)
            if req.generated >= self._target_len(req):
                # the prefill-emitted token already satisfied the request
                # (output_len == 1): finish at join, never occupy a slot.
                # Stash it so the next finish_step reports it to the
                # runtime (closed-loop refill); when no step ever runs the
                # request still settles — the realtime loop's
                # _all_settled early-exit covers the open-loop path.
                # KNOWN LIMIT: if NO step ever runs on this instance the
                # stash is never reported — closed_loop refill and the
                # watchdog's on_step_end ack miss it (an idle instance may
                # be spuriously drained once, harmlessly, under
                # watchdog_multiplier > 0).  Fixing this needs a
                # completion channel besides finish_step on the
                # EnginePlane contract.
                if req.first_token_time is None:
                    req.first_token_time = now
                req.finish_time = now
                req.phase = RequestPhase.FINISHED
                gen.cache = None
                by_id[dp_id].release(
                    req.input_len + req.generated,
                    reserve_len=req.input_len + req.output_len)
                self._deferred.discard(req.rid)
                self._join_finished.append(req)
                continue
            # padded: admission token = a free slot; paged: a free slot
            # AND the request's lifetime blocks (reserved up front so a
            # resident request never stalls mid-generation on a page;
            # share_prefix holds one block of slack for the eager tail
            # copy-on-write in _join_pages)
            life = self.spec.lifetime_tokens(req)
            use_binder = (self.spec.paged and st.binder is not None
                          and st.pool.blocks_for(life)
                          < st.pool.num_blocks - 1)
            # CLAIM FIRST: take the refs on the resident prefix before
            # the admission check so (a) the check credits pages that
            # will be pointed at rather than allocated — a prefix-heavy
            # request must not defer behind blocks it doesn't need — and
            # (b) ensure_free's LRU eviction can never free the pages
            # between the check and the join
            claim, shared = 0, []
            toks = (req.tokens or ())[:req.input_len]
            if (use_binder and toks
                    and isinstance(gen.cache, PageHandoff)):
                claim, shared, _first = st.binder.claim(toks)
            if not st.can_admit(life, extra_blocks=1 if use_binder else 0,
                                shared_blocks=len(shared)):
                if shared:
                    st.pool.free(shared)     # drop the claim's refs
                self._deferred.add(req.rid)
                still.append((dp_id, req))   # retry after this step
                continue
            self._deferred.discard(req.rid)
            slot = st.free_slot()
            if st.cache is None:
                st.cache = (self.spec.paged_cache() if self.spec.paged
                            else self.spec.batch_cache())
            if self.spec.paged and isinstance(gen.cache, PageHandoff):
                self._join_pages(st, gen, req, slot, use_binder,
                                 claim, shared)
            elif self.spec.paged:
                ids = st.pool.alloc(st.pool.blocks_for(life))
                st.held[req.rid] = ids
                tab = jnp.asarray(pad_block_table(ids, self.spec.nbt),
                                  jnp.int32)
                st.cache = self.spec.jit_paged_join(st.cache, gen.cache,
                                                    slot, tab)
            else:
                st.cache = self.spec.jit_join(st.cache, gen.cache, slot)
            gen.cache = None        # resident now; parked copy released
            st.slots[slot] = req
            st.next_tok[slot] = gen.tokens[-1]
            self._slot_of[req.rid] = (dp_id, slot)
            self.running[dp_id].append(req)
            self.peak_resident = max(self.peak_resident, len(self._slot_of))
        self._pending = still

    def _join_pages(self, st: "_DPPagedState", gen: GenState, req: Request,
                    slot: int, use_binder: bool,
                    claim: int = 0, shared: Sequence[int] = ()) -> None:
        """Adopt a `PageHandoff` into this DP: prefix blocks already
        resident (binder claim — taken by the CALLER before the admission
        check, refs held) are POINTED AT, the rest of the payload is
        copied into fresh blocks, growth blocks get their stale kv_pos
        cleared.  Then the prompt's pages are published into the DP's own
        prefix cache; binding makes the partial tail block shared, and
        the request's first decode write lands exactly there — so the
        copy-on-write divergence is handled EAGERLY, now, while no step
        is in flight, leaving the cached tail frozen at input_len."""
        ph: PageHandoff = gen.cache
        bs = self.spec.block_size
        toks = (req.tokens or ())[:req.input_len]
        n_all = st.pool.blocks_for(self.spec.lifetime_tokens(req))
        n_payload = st.pool.blocks_for(req.input_len)
        shared = list(shared)
        if use_binder and toks:
            # hit stats recorded only on a successful join — a deferred
            # admission must not double-count its retries
            st.binder.record(claim, req.input_len)
        n_shared = len(shared)
        self.blocks_shared += n_shared
        table = list(shared) + st.pool.alloc(n_all - n_shared)
        st.held[req.rid] = table
        idx = jnp.arange(self.spec.nbt)
        copy_mask = (idx >= n_shared) & (idx < n_payload)
        clear_mask = (idx >= n_payload) & (idx < n_all)
        tab = jnp.asarray(pad_block_table(table, self.spec.nbt), jnp.int32)
        st.cache = self.spec.jit_adopt_blocks(
            st.cache, ph.payload, slot, tab, copy_mask, clear_mask,
            req.input_len)
        if not use_binder or not toks:
            return
        st.binder.insert(toks, table[:n_payload],
                         first_token=gen.tokens[0])
        lw = req.input_len // bs
        if req.input_len % bs and st.pool.is_shared(table[lw]):
            # eager COW: the admission slack block becomes the private
            # tail; the cached copy stays frozen for future exact hits
            new = st.pool.alloc(1)[0]
            old = table[lw]
            st.cache = self.spec.jit_copy_block(st.cache, old, new)
            st.cache = self.spec.run_eager(
                lambda c: dict(c, block_tab=c["block_tab"]
                               .at[slot, lw].set(new)),
                st.cache)
            table[lw] = new
            st.pool.free([old])
            self.cow_copies += 1

    def start_step(self, dp_states, now: Optional[float] = None
                   ) -> StartResult:
        self._raise_worker_error()
        if self.busy:
            return None
        if self._pending:
            self._apply_joins(now if now is not None else 0.0, dp_states)
        if not super().has_work():
            return None
        self.busy = True
        self.steps += 1
        jobs: List[Tuple[int, Dict, jnp.ndarray]] = []
        self._participants = {}
        for d in self.dp_ids:
            st = self._dp[d]
            if not self.running[d]:
                continue
            self._participants[d] = [
                (r, self._slot_of[r.rid][1]) for r in self.running[d]]
            if self.spec.sharded:
                continue            # ONE merged cross-DP job, built below
            toks = jnp.asarray([[t] for t in st.next_tok], jnp.int32)
            jobs.append((d, st.cache, toks))
        if self.spec.sharded and self._participants:
            # the instance sync barrier now lives INSIDE the program: one
            # step over the merged cache advances every DP's rows under
            # the same mesh collectives (dp_id -1 marks the merged job)
            g = self._group
            toks = jnp.asarray([[t] for t in g.next_tok], jnp.int32)
            jobs.append((-1, g.cache, toks))
        self._step_active = sum(len(v) for v in self._participants.values())
        self._step_rows = (len(self._group.slots) if self.spec.sharded
                           else sum(len(self._dp[d].slots)
                                    for d in self._participants))
        epoch = self.epoch
        post = self._post
        self._worker.submit(lambda: self._exec_step(jobs, epoch, post))
        return ASYNC

    def _exec_step(self, jobs, epoch: int, post) -> None:
        # worker thread: one batched decode_step per occupied DP (the
        # instance-level sync barrier = all DPs in one serial job)
        t0 = time.monotonic()
        step = (self.spec.jit_paged_decode if self.spec.paged
                else self.spec.jit_decode)
        try:
            res: Dict[int, Tuple[Dict, List[int]]] = {}
            for dp_id, cache, toks in jobs:
                with self.spec.device_lock():
                    logits, new_cache = step(self.spec.params, toks, cache)
                    nxt = [int(x) for x in jnp.argmax(logits, axis=-1)]
                if dp_id < 0:
                    # merged cross-DP job: slots are global, so the same
                    # (cache, next-token) pair fans back to every
                    # participating DP — finish_step indexes nxt by the
                    # participant's global slot unchanged
                    for d in self._participants:
                        res[d] = (new_cache, nxt)
                else:
                    res[dp_id] = (new_cache, nxt)
            self._result = res
        except BaseException as e:      # surface on the runtime thread
            self._error = e
        dur = time.monotonic() - t0
        self.step_samples.append((dur, self._step_active, self._step_rows))
        post("step_end", (self, epoch, dur))

    def finish_step(self, now: float, dp_states) -> List[Request]:
        self._raise_worker_error()
        res, self._result = self._result, None
        parts, self._participants = self._participants, {}
        assert res is not None
        for dp_id, (new_cache, nxt) in res.items():
            st = self._dp[dp_id]
            st.cache = new_cache
            for req, slot in parts.get(dp_id, []):
                tok = nxt[slot]
                self.bus.gen(req.rid).tokens.append(tok)
                st.next_tok[slot] = tok
        finished = super().finish_step(now, dp_states)
        for req in finished:
            dp_id, slot = self._slot_of.pop(req.rid)
            st = self._dp[dp_id]
            st.slots[slot] = None                    # leave-on-finish
            if self.spec.paged:
                # drop the table row FIRST: the now-inactive slot keeps
                # stepping on garbage, and its writes must route to the
                # null block, never to pages the pool re-issues
                st.cache = self.spec.run_eager(
                    paged_cache_clear_slot, st.cache, slot)
                st.pool.free(st.held.pop(req.rid))
        if self._join_finished:
            # requests satisfied at join time (never occupied a slot):
            # report them with this step's completions so the runtime's
            # closed-loop refill sees every finish
            finished = self._join_finished + finished
            self._join_finished = []
        return finished

    def drain(self) -> Dict[int, List[Request]]:
        out = super().drain()   # clears running, bumps epoch, unlocks
        # migrate resident KV back to the bus so re-dispatch can re-join
        # the requests (with their generation state) on a healthy instance
        for rid, (dp_id, slot) in list(self._slot_of.items()):
            st = self._dp[dp_id]
            if self.spec.paged:
                # eager (unjitted), like the padded cache_take branch: the
                # drain path is rare and per-slot jit specialisation would
                # compile a fresh gather program mid-recovery
                self.bus.gen(rid).cache = self.spec.run_eager(
                    paged_cache_take, self.spec.cfg, st.cache, slot)
                st.cache = self.spec.run_eager(
                    paged_cache_clear_slot, st.cache, slot)
                st.pool.free(st.held.pop(rid))
            else:
                self.bus.gen(rid).cache = cache_take(st.cache, slot)
            st.slots[slot] = None
        self._slot_of.clear()
        for dp_id, req in self._pending:
            out.setdefault(dp_id, []).append(req)
        self._pending = []
        self._deferred.clear()
        self._participants = {}
        self._result = None
        return out

# ---------------------------------------------------------------------------
# Real unified mixed-batch engine
# ---------------------------------------------------------------------------


class RealUnifiedEngine(RealDecodeEngine, UnifiedEngine):
    """Unified mixed-batch engine (paged only): one pool, one step loop.

    Raw requests (no published generation state) are staged as
    PREFILLING RESIDENTS at join time: their lifetime pages are reserved
    and their table row installed with `cur = 0`, exactly like
    `RealPrefillEngine._stage` — but into the DECODE pool, so no KV
    handoff ever happens.  Each step then runs `mixed_step`: the decode
    rows' batched forward plus as many pending prefill-chunk tokens as
    fit the leftover budget (`chunk − decode_rows`), in ONE XLA program.
    The decode half is MASKED to the actively-decoding slots — a
    prefilling resident's table row is live, so an unmasked decode would
    scribble a garbage token into its pages and bump its cursor.

    Chunk grants are quantized to `block_size` multiples (except a
    prompt's final chunk) to bound jit retraces; the starvation bound
    (`starve_limit`) forces a minimum grant when decode rows hog the
    budget.  `piggyback=False` is the DISJOINT ablation (the
    prefill-prioritizing chunked loop Sarathi measures against): a step
    with pending prefill runs ONLY the prefill chunk while the decode
    rows stall — the ITL bubble the unified plane exists to remove."""

    def __init__(self, instance_id: int, dp_ids: Sequence[int],
                 spec: EngineSpec, bus: KVHandoffBus, chunk: int = 256,
                 starve_limit: int = 4, piggyback: bool = True,
                 share_prefix: bool = False):
        if not spec.paged:
            raise ValueError(
                "the unified mixed-batch engine requires block_size > 0 "
                "(prefill chunks ride paged_prefill_step into the pool)")
        _require_pageable_prefill(spec.cfg)
        super().__init__(instance_id, dp_ids, spec, bus,
                         share_prefix=share_prefix)
        self.chunk = max(int(chunk), 1)
        self.starve_limit = max(int(starve_limit), 1)
        self.piggyback = piggyback
        self.prefilling: Dict[int, "collections.deque[Request]"] = {
            d: collections.deque() for d in dp_ids}
        self._consumed: Dict[int, int] = {}       # rid -> prompt tokens done
        self._starve: Dict[int, int] = {d: 0 for d in dp_ids}
        self._grants: Dict[int, List[Tuple[Request, int]]] = {}
        self._chunk_result: Optional[Dict[int, List[int]]] = None
        self._stalled: set = set()
        self.prefill_tokens = 0
        self.forced_grants = 0      # starvation-bound activations
        self.mixed_steps = 0        # steps that ran decode+prefill fused

    # -- EnginePlane -----------------------------------------------------
    def has_work(self) -> bool:
        return (super().has_work()
                or any(self.prefilling[d] for d in self.dp_ids))

    def prefill_backlog(self) -> int:
        return sum(r.input_len - self._consumed[r.rid]
                   for d in self.dp_ids for r in self.prefilling[d])

    def _apply_joins(self, now: float, dp_states) -> None:
        # handed-off requests (drain re-parks, preemption re-admits) ride
        # the parent join path; RAW requests — no transferred KV on the
        # bus — stage as prefilling residents.  A bus entry WITHOUT a
        # cache is one this plane published itself (unified prefill
        # completions set gen.cache = None), e.g. a re-served rid from a
        # previous run on the same deployment: still raw
        raw: List[Tuple[int, Request]] = []
        rest: List[Tuple[int, Request]] = []
        for item in self._pending:
            gen = self.bus.get(item[1].rid)
            (raw if gen is None or gen.cache is None else rest).append(item)
        self._pending = rest
        super()._apply_joins(now, dp_states)
        still: List[Tuple[int, Request]] = []
        for dp_id, req in raw:
            st = self._dp[dp_id]
            life = self.spec.lifetime_tokens(req)
            if not st.can_admit(life):
                self._deferred.add(req.rid)
                still.append((dp_id, req))
                continue
            self._deferred.discard(req.rid)
            slot = st.free_slot()
            if st.cache is None:
                st.cache = self.spec.paged_cache()
            ids = st.pool.alloc(st.pool.blocks_for(life))
            st.held[req.rid] = ids
            arr = jnp.asarray(pad_block_table(ids, self.spec.nbt), jnp.int32)
            # reused pages keep their previous tenant's kv_pos; stale
            # pos <= the reader's cursor would alias as valid history
            st.cache = self.spec.jit_clear_rows(st.cache, arr)
            st.cache = self.spec.run_eager(
                lambda c: dict(c, block_tab=c["block_tab"].at[slot].set(arr),
                               cur=c["cur"].at[slot].set(0)),
                st.cache)
            st.slots[slot] = req
            self._slot_of[req.rid] = (dp_id, slot)
            self._consumed[req.rid] = 0
            self.prefilling[dp_id].append(req)
            self.peak_resident = max(self.peak_resident, len(self._slot_of))
        self._pending.extend(still)

    # -- budget split ----------------------------------------------------
    def _form_grants(self, d: int, n_decode: int, now: float
                     ) -> List[Tuple[Request, int]]:
        q = self.prefilling[d]
        if not q:
            self._starve[d] = 0
            return []
        # disjoint ablation: prefill-prioritizing baseline — the full
        # chunk budget every step, decode rows stall while it runs
        budget = self.chunk - n_decode if self.piggyback else self.chunk
        if budget <= 0:
            self._starve[d] += 1
            if self._starve[d] < self.starve_limit:
                return []
            budget = max(1, self.chunk // 4)    # forced minimum grant
            self.forced_grants += 1
        bs = self.spec.block_size
        grants: List[Tuple[Request, int]] = []
        for req in q:
            if budget <= 0:
                break
            remaining = req.input_len - self._consumed[req.rid]
            use = min(remaining, budget)
            if use < remaining:
                # partial chunks land on block boundaries: bounds jit
                # retraces to block-multiple shapes + final-chunk shapes
                use = (use // bs) * bs
                if use <= 0:
                    break
            if req.prefill_start is None:
                req.prefill_start = now
            grants.append((req, use))
            budget -= use
            # one chunk per DP per step: each extra chunk in the tuple
            # multiplies the jit_mixed shape lattice (every combination
            # of chunk lengths is a fresh trace), and a single grant
            # keeps prefill FIFO anyway — leftover budget just waits a
            # step
            break
        if grants:
            self._starve[d] = 0
        return grants

    def start_step(self, dp_states, now: Optional[float] = None
                   ) -> StartResult:
        self._raise_worker_error()
        if self.busy:
            return None
        if self._pending:
            self._apply_joins(now if now is not None else 0.0, dp_states)
        if not (SimDecodeInstance.has_work(self)
                or any(self.prefilling[d] for d in self.dp_ids)):
            return None
        tnow = now if now is not None else 0.0
        jobs: List[Tuple[int, Dict, Optional[jnp.ndarray], tuple,
                         Optional[jnp.ndarray]]] = []
        self._participants = {}
        self._grants = {}
        self._stalled = set()
        for d in self.dp_ids:
            st = self._dp[d]
            rows = self.running[d]
            grants = self._form_grants(d, len(rows), tnow)
            if grants:
                self._grants[d] = grants
            stall = bool(grants) and not self.piggyback and bool(rows)
            if stall:
                self._stalled.add(d)
            decode_rows = [] if stall else rows
            if not decode_rows and not grants:
                continue
            chunks = []
            for req, use in grants:
                c0 = self._consumed[req.rid]
                ids = list((req.tokens or ())[c0: c0 + use])
                chunks.append((jnp.asarray([ids], jnp.int32),
                               jnp.int32(self._slot_of[req.rid][1])))
            toks = mask = None
            if decode_rows:
                self._participants[d] = [
                    (r, self._slot_of[r.rid][1]) for r in decode_rows]
                toks = jnp.asarray([[t] for t in st.next_tok], jnp.int32)
                if chunks or self.prefilling[d]:
                    # prefilling residents have LIVE table rows: mask the
                    # decode half to the actively-decoding slots
                    m = [False] * len(st.slots)
                    for _r, s in self._participants[d]:
                        m[s] = True
                    mask = jnp.asarray(m)
            jobs.append((d, st.cache, toks, tuple(chunks), mask))
        if not jobs:
            return None
        if self.spec.sharded:
            jobs = self._merge_sharded_jobs(jobs)
        self.busy = True
        self.steps += 1
        self._step_active = sum(len(v) for v in self._participants.values())
        self._step_rows = (
            len(self._group.slots) if self.spec.sharded
            else sum(len(self._dp[d].slots)
                     for d, _c, toks, _ch, _m in jobs if toks is not None))
        epoch = self.epoch
        post = self._post
        self._worker.submit(lambda: self._exec_mixed(jobs, epoch, post))
        return ASYNC

    def _merge_sharded_jobs(self, jobs):
        """Collapse the per-DP mixed jobs into ONE cross-DP program over
        the merged cache: global decode-token rows, every DP's chunk
        grant in one tuple (slot ids are already global — grant order
        matches `self._grants` iteration order, which the fan-back in
        `_exec_mixed` relies on), one decode mask over the merged slot
        axis.  The mask is unconditional whenever anything decodes: all
        DPs share the one cache, so another DP's prefilling (or
        disjoint-stalled) resident rows must never see a decode write."""
        g = self._group
        chunks = tuple(c for _d, _c, _t, cs, _m in jobs for c in cs)
        if not self._participants:
            return [(-1, g.cache, None, chunks, None)]
        toks = jnp.asarray([[t] for t in g.next_tok], jnp.int32)
        m = [False] * len(g.slots)
        for lst in self._participants.values():
            for _r, s in lst:
                m[s] = True
        return [(-1, g.cache, toks, chunks, jnp.asarray(m))]

    def _exec_mixed(self, jobs, epoch: int, post) -> None:
        # worker thread: one fused mixed step per DP with decode rows
        # (masked when prefilling residents share the cache), a plain
        # paged decode when nothing is prefilling, a serial chunk loop
        # when nothing is decoding
        t0 = time.monotonic()
        try:
            res: Dict[int, Tuple[Dict, List[int]]] = {}
            cres: Dict[int, List[int]] = {}
            for dp_id, cache, toks, chunks, mask in jobs:
                with self.spec.device_lock():
                    if toks is None:
                        new_cache = cache
                        clogits = []
                        for ctoks, slot in chunks:
                            lg, new_cache = self.spec.jit_paged_prefill(
                                self.spec.params, ctoks, new_cache, slot)
                            clogits.append(lg)
                        nxt: List[int] = []
                    elif mask is not None:
                        logits, clogits, new_cache = self.spec.jit_mixed(
                            self.spec.params, toks, cache, chunks, mask)
                        if chunks:
                            self.mixed_steps += 1
                        nxt = [int(x) for x in jnp.argmax(logits, axis=-1)]
                    else:
                        logits, new_cache = self.spec.jit_paged_decode(
                            self.spec.params, toks, cache)
                        clogits = ()
                        nxt = [int(x) for x in jnp.argmax(logits, axis=-1)]
                    firsts = [int(jnp.argmax(lg[0])) for lg in clogits]
                if dp_id < 0:
                    # merged cross-DP job: one cache/next-token pair fans
                    # back to every decoding DP (slots are global); the
                    # flat chunk firsts split by per-DP grant counts in
                    # the same order _merge_sharded_jobs flattened them
                    i = 0
                    for d, lst in self._grants.items():
                        cres[d] = firsts[i:i + len(lst)]
                        i += len(lst)
                    if self._participants:
                        for d in self._participants:
                            res[d] = (new_cache, nxt)
                    else:
                        res[self.dp_ids[0]] = (new_cache, [])
                    continue
                res[dp_id] = (new_cache, nxt)
                cres[dp_id] = firsts
            self._result = res
            self._chunk_result = cres
        except BaseException as e:      # surface on the runtime thread
            self._error = e
        dur = time.monotonic() - t0
        self.step_samples.append((dur, self._step_active, self._step_rows))
        post("step_end", (self, epoch, dur))

    def finish_step(self, now: float, dp_states) -> List[Request]:
        cres = self._chunk_result or {}
        self._chunk_result = None
        grants, self._grants = self._grants, {}
        stalled, self._stalled = self._stalled, set()
        by_id = {s.dp_id: s for s in dp_states}
        # disjoint-stall steps: detach the stalled DPs' rows so the
        # parent pass emits nothing for them (that stall IS the ablation)
        saved = {d: self.running[d] for d in stalled}
        for d in stalled:
            self.running[d] = []
        finished = super().finish_step(now, dp_states)
        for d, rows in saved.items():
            self.running[d] = rows + self.running[d]
        # prefill half: account granted tokens; a completed prompt
        # publishes its first token (argmax of the chunk's last position)
        # and graduates to the decode rows — no handoff, same pool
        for d, lst in grants.items():
            st = self._dp[d]
            sched = by_id[d]
            firsts = cres.get(d, [])
            q = self.prefilling[d]
            for i, (req, use) in enumerate(lst):
                self._consumed[req.rid] += use
                req.remaining_prefill = max(
                    req.input_len - self._consumed[req.rid], 0)
                self.prefill_tokens += use
                if self._consumed[req.rid] < req.input_len:
                    continue
                first = firsts[i]
                q.remove(req)
                del self._consumed[req.rid]
                gen = self.bus.publish(req.rid, None, first)
                gen.cache = None            # resident already — no payload
                sched.step(1)               # the emitted token's KV entry
                req.generated += 1
                if req.first_token_time is None:
                    req.first_token_time = now
                self._record_emit(req.rid, now)
                slot = self._slot_of[req.rid][1]
                if req.generated >= self._target_len(req):
                    req.finish_time = now
                    sched.release(req.input_len + req.generated,
                                  reserve_len=req.input_len + req.output_len)
                    self._last_emit.pop(req.rid, None)
                    self._slot_of.pop(req.rid)
                    st.cache = self.spec.run_eager(
                        paged_cache_clear_slot, st.cache, slot)
                    st.slots[slot] = None
                    st.pool.free(st.held.pop(req.rid))
                    finished.append(req)
                else:
                    st.next_tok[slot] = first
                    self.running[d].append(req)
        return finished

    def drain(self) -> Dict[int, List[Request]]:
        # prefilling residents have no parked generation state: drop
        # their partial KV (pages back to the pool) and restart prefill
        # wherever re-dispatch lands them
        pre: Dict[int, List[Request]] = {}
        for d in self.dp_ids:
            q = self.prefilling[d]
            if not q:
                self._starve[d] = 0
                continue
            pre[d] = list(q)
            q.clear()
            st = self._dp[d]
            for req in pre[d]:
                _dp, slot = self._slot_of.pop(req.rid)
                st.cache = self.spec.run_eager(
                    paged_cache_clear_slot, st.cache, slot)
                st.slots[slot] = None
                st.pool.free(st.held.pop(req.rid))
                del self._consumed[req.rid]
                req.remaining_prefill = req.input_len
            self._starve[d] = 0
        out = super().drain()
        for d, reqs in pre.items():
            out.setdefault(d, []).extend(reqs)
        self._grants = {}
        self._chunk_result = None
        self._stalled = set()
        return out
