"""End-to-end P/D-disaggregated cluster simulation (the paper's 3P1D
deployment): requests flow prefill pool → KV-cache transfer (ICI/DCN) →
decode pool, with SBS or immediate scheduling on BOTH phases.  The event
loop is the unified `repro.serving.runtime.ClusterRuntime` — this module
only wires the two planes together and derives the report.

Metrics: TTFT (arrival → first token, includes the transfer), TPOT, E2E
latency, and goodput (requests completing within an SLO).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

from repro.config.base import ModelConfig, ServingConfig
from repro.core.flow_control import FlowController
from repro.core.types import Request
from repro.serving.cluster import (
    build_decode_instances, build_decode_scheduler, build_prefill_instances,
    build_prefill_scheduler, build_state,
)
from repro.serving.costmodel import CostModel, ICI_BW
from repro.serving.metrics import goodput_by_class, mean, percentile
from repro.serving.runtime import ClusterRuntime


@dataclasses.dataclass
class E2EReport:
    n_finished: int
    ttft_mean: float
    ttft_p50: float
    ttft_p99: float
    tpot_mean: float
    e2e_mean: float
    goodput: float                  # fraction finishing within slo_e2e
    prefill_util: float
    throughput: float = 0.0        # decode tokens / s over the run
    # inter-token latency (gap between consecutive emissions of one
    # request) — the unified mixed-batch plane's tentpole metric: decode
    # stalls behind disjoint prefill passes surface as a fat ITL p99
    itl_p50: float = 0.0
    itl_p99: float = 0.0
    prefix_hit_rate: float = 0.0   # cached prefix tokens / prompt tokens
    prefill_flops_saved: float = 0.0   # FLOPs skipped via prefix reuse
    # SLO-aware overload control (all zero/empty when it is off)
    goodput_by_class: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    rejected: int = 0              # flow-control rejections
    preemptions: int = 0           # page-level swap-out events

    def row(self) -> str:
        out = (f"n={self.n_finished} ttft={self.ttft_mean*1000:.0f}ms "
               f"p99={self.ttft_p99*1000:.0f}ms "
               f"tpot={self.tpot_mean*1000:.1f}ms "
               f"itl_p99={self.itl_p99*1000:.1f}ms "
               f"e2e={self.e2e_mean:.2f}s goodput={self.goodput*100:.1f}% "
               f"util={self.prefill_util*100:.1f}% "
               f"thr={self.throughput:.0f} tok/s")
        if self.prefix_hit_rate:
            out += (f" hit={self.prefix_hit_rate*100:.1f}% "
                    f"saved={self.prefill_flops_saved:.2e}FLOPs")
        if self.rejected or self.preemptions:
            out += f" rej={self.rejected} preempt={self.preemptions}"
        if len(self.goodput_by_class) > 1:
            out += " [" + " ".join(
                f"{c}={g*100:.0f}%"
                for c, g in self.goodput_by_class.items()) + "]"
        return out

    def json_row(self) -> dict:
        return {"n_finished": self.n_finished,
                "ttft_p50": self.ttft_p50, "ttft_p99": self.ttft_p99,
                "ttft_mean": self.ttft_mean, "tpot_mean": self.tpot_mean,
                "itl_p50": self.itl_p50, "itl_p99": self.itl_p99,
                "throughput": self.throughput, "goodput": self.goodput,
                "prefix_hit_rate": self.prefix_hit_rate,
                "prefill_flops_saved": self.prefill_flops_saved,
                "goodput_by_class": self.goodput_by_class,
                "rejected": self.rejected,
                "preemptions": self.preemptions}


class PDClusterSim:
    """3P1D-style pipeline with KV transfer between the pools.

    scheduler ∈ {sbs, sbs-la, immediate}: 'sbs-la' keeps SBS on the
    prefill side but switches decode to Load-Aware Global Allocation."""

    def __init__(self, model_cfg: ModelConfig, scfg: ServingConfig,
                 scheduler: str = "sbs", cost: Optional[CostModel] = None,
                 transfer_bw: float = ICI_BW,
                 watchdog_multiplier: float = 0.0):
        self.cfg = model_cfg
        self.scfg = scfg
        self.cost = cost or CostModel(model_cfg)
        self.state = build_state(scfg)
        self.transfer_bw = transfer_bw
        if scheduler not in ("sbs", "sbs-la", "immediate"):
            raise ValueError(scheduler)
        if scfg.mixed_batch:
            # unified mixed-batch plane: DECODE-POOL-ONLY deployment —
            # arrivals hand off straight to the decode scheduler and the
            # unified instances run chunked prefill piggybacked on their
            # own steps (no prefill pool, no KV transfer)
            self.psched = None
            self.prefill = []
        elif scheduler == "immediate":
            self.psched = build_prefill_scheduler(self.state, scfg,
                                                  "immediate-rr")
            self.prefill = build_prefill_instances(self.state, scfg,
                                                   self.cost)
        else:
            self.psched = build_prefill_scheduler(self.state, scfg, "sbs")
            self.prefill = build_prefill_instances(self.state, scfg,
                                                   self.cost)
        self.dsched = build_decode_scheduler(
            self.state, scfg, scheduler,
            watchdog_multiplier=watchdog_multiplier)
        self.decode = build_decode_instances(self.state, scfg, self.cost)
        flow = (FlowController(n_limit=scfg.n_limit,
                               backoff_base=scfg.flow_backoff)
                if scfg.flow_control else None)
        self.runtime = ClusterRuntime(
            self.state, prefill_sched=self.psched,
            prefill_instances=self.prefill or None,
            decode_sched=self.dsched,
            decode_instances=self.decode,
            transfer_time=None if scfg.mixed_batch else self._transfer_time,
            flow=flow, preemption=scfg.preemption)

    def _transfer_time(self, req: Request) -> float:
        bytes_ = self.cost.kv_bytes_per_token * req.input_len
        return bytes_ / self.transfer_bw + 0.002

    def run(self, requests: Sequence[Request], duration: float,
            slo_e2e: Optional[float] = None) -> E2EReport:
        slo = slo_e2e if slo_e2e is not None else self.scfg.slo_default
        end = self.runtime.run(requests, duration,
                               horizon=duration * 30 + 120.0)
        done = [r for r in requests if r.finish_time is not None]
        ttfts = [r.ttft for r in done if r.ttft is not None]
        tpots = [(r.finish_time - r.first_token_time) / max(r.generated - 1, 1)
                 for r in done if r.first_token_time is not None]
        e2e = [r.finish_time - r.arrival_time for r in done]
        # goodput = SLO-attained throughput: a request's own slo_e2e (its
        # priority class) wins over the deployment default; rejected and
        # unfinished requests stay in the denominator
        good = (sum(1 for r in requests if r.slo_attained(slo))
                / max(len(requests), 1))
        # prefix-reuse accounting: the sim prices savings with the SAME
        # cost model the dispatcher uses, so sim and real planes share one
        # reuse model (the real plane reports engine-truth counters via
        # RealSBSServer.prefix_stats instead)
        cache = getattr(self.psched, "cache", None)
        hit_rate = cache.hit_rate if cache is not None else 0.0
        saved = (self.cost.prefill_flops(cache.hit_tokens)
                 if cache is not None and cache.hit_tokens else 0.0)
        itls = [s for inst in self.decode
                for s in getattr(inst, "itl", [])]
        return E2EReport(
            n_finished=len(done),
            ttft_mean=mean(ttfts), ttft_p50=percentile(ttfts, 50),
            ttft_p99=percentile(ttfts, 99),
            tpot_mean=mean(tpots), e2e_mean=mean(e2e),
            itl_p50=percentile(itls, 50) if itls else 0.0,
            itl_p99=percentile(itls, 99) if itls else 0.0,
            goodput=good,
            prefill_util=self.runtime.prefill_util,
            throughput=self.runtime.tokens_generated / max(end, 1e-9),
            prefix_hit_rate=hit_rate, prefill_flops_saved=saved,
            goodput_by_class=goodput_by_class(requests, slo),
            rejected=len(self.runtime.rejected),
            preemptions=len(self.runtime.preempted))
