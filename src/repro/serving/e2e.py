"""End-to-end P/D-disaggregated cluster simulation (the paper's 3P1D
deployment): requests flow prefill pool → KV-cache transfer (ICI/DCN) →
decode pool, with SBS or immediate scheduling on BOTH phases.

Metrics: TTFT (arrival → first token, includes the transfer), TPOT, E2E
latency, and goodput (requests completing within an SLO).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Dict, List, Optional, Sequence

from repro.config.base import ModelConfig, ServingConfig
from repro.core.scheduler import (
    DecodeScheduler, ImmediatePrefillScheduler, StaggeredBatchScheduler,
)
from repro.core.types import Request, RequestPhase
from repro.serving.cluster import _EventLoop, build_state
from repro.serving.costmodel import CostModel, ICI_BW
from repro.serving.engine import SimDecodeInstance, SimPrefillInstance
from repro.serving.metrics import mean, percentile


@dataclasses.dataclass
class E2EReport:
    n_finished: int
    ttft_mean: float
    ttft_p99: float
    tpot_mean: float
    e2e_mean: float
    goodput: float                  # fraction finishing within slo_e2e
    prefill_util: float

    def row(self) -> str:
        return (f"n={self.n_finished} ttft={self.ttft_mean*1000:.0f}ms "
                f"p99={self.ttft_p99*1000:.0f}ms "
                f"tpot={self.tpot_mean*1000:.1f}ms "
                f"e2e={self.e2e_mean:.2f}s goodput={self.goodput*100:.1f}% "
                f"util={self.prefill_util*100:.1f}%")


class PDClusterSim:
    """3P1D-style pipeline with KV transfer between the pools."""

    def __init__(self, model_cfg: ModelConfig, scfg: ServingConfig,
                 scheduler: str = "sbs", cost: Optional[CostModel] = None,
                 transfer_bw: float = ICI_BW):
        self.cfg = model_cfg
        self.scfg = scfg
        self.cost = cost or CostModel(model_cfg)
        self.state = build_state(scfg)
        self.transfer_bw = transfer_bw
        if scheduler == "sbs":
            self.psched = StaggeredBatchScheduler(self.state,
                                                  n_limit=scfg.n_limit)
            self.dsched = DecodeScheduler(self.state, mode="sbs",
                                          iqr_k=scfg.iqr_k)
        else:
            self.psched = ImmediatePrefillScheduler(self.state)
            self.dsched = DecodeScheduler(self.state, mode="immediate",
                                          policy="round_robin")
        self.prefill = [
            SimPrefillInstance(
                i, [d.dp_id for d in self.state.prefill_dps_of(i)],
                scfg.chunk_size, self.cost)
            for i in range(scfg.num_prefill_instances)]
        self.decode = [
            SimDecodeInstance(
                i, [d.dp_id for d in self.state.decode_dps_of(i)], self.cost)
            for i in range(scfg.num_decode_instances)]
        self._dp2dinst = {d.dp_id: d.instance_id
                          for d in self.state.decode_dps}
        self._pass_start: Dict[int, float] = {}

    def _transfer_time(self, req: Request) -> float:
        bytes_ = self.cost.kv_bytes_per_token * req.input_len
        return bytes_ / self.transfer_bw + 0.002

    def run(self, requests: Sequence[Request], duration: float,
            slo_e2e: float = 20.0) -> E2EReport:
        ev = _EventLoop()
        for r in requests:
            ev.push(r.arrival_time, "arrival", r)
        now = 0.0
        horizon = duration * 30 + 120.0
        while ev:
            now, _, kind, payload = ev.pop()
            if now > horizon:
                break
            if kind == "arrival":
                self.psched.on_arrival(payload, now)
            elif kind == "pass_end":
                inst: SimPrefillInstance = payload
                start = self._pass_start.pop(inst.instance_id)
                res = inst.finish_pass(now)
                for e in res.end_forwards:
                    e.exec_time = now - start
                    self.psched.on_end_forward(e)
                for req in res.completed:
                    # prefill done: ship the KV cache to the decode pool
                    ev.push(now + self._transfer_time(req), "kv_arrived", req)
            elif kind == "kv_arrived":
                req: Request = payload
                req.first_token_time = None       # TTFT set by decode
                req.phase = RequestPhase.DECODING
                place = self.dsched.on_handoff(req, now)
                self._place(place)
            elif kind == "decode_end":
                dinst: SimDecodeInstance = payload
                dinst.finish_step(now, self.state.decode_dps)
            # drive both schedulers + engines
            for cmd in self.psched.poll(now):
                self.prefill[cmd.instance_id].enqueue(cmd, now)
            self._place(self.dsched.poll(now))
            for inst in self.prefill:
                dur = inst.start_pass(now)
                if dur is not None:
                    self._pass_start[inst.instance_id] = now
                    ev.push(now + dur, "pass_end", inst)
            for dinst in self.decode:
                dur = dinst.start_step(self.state.decode_dps)
                if dur is not None:
                    ev.push(now + dur, "decode_end", dinst)
            nxt = self.psched.next_event_time(now)
            if nxt is not None and nxt > now:
                ev.push(nxt, "tick", None)
            nd = self.dsched.next_event_time(now)
            if nd is not None and nd > now:
                ev.push(nd, "tick", None)

        done = [r for r in requests if r.finish_time is not None]
        ttfts = [r.ttft for r in done if r.ttft is not None]
        tpots = [(r.finish_time - r.first_token_time) / max(r.generated - 1, 1)
                 for r in done if r.first_token_time is not None]
        e2e = [r.finish_time - r.arrival_time for r in done]
        util = (sum(i.tokens_processed for i in self.prefill)
                / max(sum(i.capacity_offered for i in self.prefill), 1))
        good = sum(1 for x in e2e if x <= slo_e2e) / max(len(requests), 1)
        return E2EReport(
            n_finished=len(done),
            ttft_mean=mean(ttfts), ttft_p99=percentile(ttfts, 99),
            tpot_mean=mean(tpots), e2e_mean=mean(e2e), goodput=good,
            prefill_util=util)

    def _place(self, placements):
        if not placements:
            return
        for dp_id, reqs in placements.items():
            inst = self.decode[self._dp2dinst[dp_id]]
            for r in reqs:
                inst.admit(dp_id, r)
