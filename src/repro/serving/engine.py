"""Simulated inference engines (the Resource Plane of Figure 5).

Both classes satisfy the `EnginePlane` contract (repro.serving.plane):
they are the cost-model-clocked backends; repro.serving.real_engine holds
the jitted-JAX backends behind the same interface.

A prefill instance is a NON-PREEMPTIVE DISCRETE BATCH PROCESSOR (§3.2):
once a pass starts the engine is locked; arriving work accumulates in the
per-DP device-side queue. The pass duration is the cost-model time of the
most-loaded DP unit (the DP+EP sync barrier of §3.3) — so imbalance shows up
as parallelization bubbles exactly as in Figure 3.
"""
from __future__ import annotations

import collections
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from repro.core.types import DispatchCommand, EndForward, Request
from repro.serving.costmodel import CostModel
from repro.serving.plane import (
    DecodeEngine, PassResult, PrefillEngine, StartResult, UnifiedEngine,
)

__all__ = ["PassResult", "SimPrefillInstance", "SimDecodeInstance",
           "SimUnifiedInstance"]


class SimPrefillInstance(PrefillEngine):
    def __init__(self, instance_id: int, dp_ids: Sequence[int],
                 chunk: int, cost: Optional[CostModel]):
        self.instance_id = instance_id
        self.dp_ids = list(dp_ids)
        self.chunk = chunk
        self.cost = cost
        self.queues: Dict[int, Deque[Tuple[Request, int]]] = {
            d: collections.deque() for d in dp_ids}
        self.busy = False
        self._current: Optional[Dict[int, List[Tuple[Request, int]]]] = None
        # stats
        self.passes = 0
        self.tokens_processed = 0
        self.capacity_offered = 0     # passes * n_dp * chunk

    # ------------------------------------------------------------------
    def enqueue(self, cmd: DispatchCommand, now: float) -> None:
        for dp_id, lst in cmd.assignments.items():
            for req, tok in lst:
                req.inflight += tok
                if tok == 0:
                    # full cache hit: completes with the next pass; keep a
                    # zero-token marker so completion is still signaled
                    self.queues[dp_id].append((req, 0))
                else:
                    self.queues[dp_id].append((req, tok))

    def backlog(self, dp_id: int) -> int:
        return sum(t for _, t in self.queues[dp_id])

    def has_work(self) -> bool:
        return any(self.queues[d] for d in self.dp_ids)

    # ------------------------------------------------------------------
    def _begin_pass(self, now: float
                    ) -> Optional[Dict[int, List[Tuple[Request, int]]]]:
        """Form the chunk-bounded per-DP batch and lock the engine.
        Shared by the simulated and real backends — only the pass
        *duration* differs (cost model vs measured wall time)."""
        if self.busy or not self.has_work():
            return None
        batch: Dict[int, List[Tuple[Request, int]]] = {}
        for d in self.dp_ids:
            budget = self.chunk
            taken: List[Tuple[Request, int]] = []
            q = self.queues[d]
            while q and budget >= 0:
                req, tok = q[0]
                if tok == 0:
                    q.popleft()
                    taken.append((req, 0))
                    continue
                if budget == 0:
                    break
                use = min(tok, budget)
                if use == tok:
                    q.popleft()
                else:
                    q[0] = (req, tok - use)
                taken.append((req, use))
                budget -= use
                if req.prefill_start is None:
                    req.prefill_start = now
            if taken:
                batch[d] = taken
        if not batch:
            return None
        self._current = batch
        self.busy = True
        self.passes += 1
        self.capacity_offered += len(self.dp_ids) * self.chunk
        return batch

    def start_pass(self, now: float) -> StartResult:
        """Begin a forward pass; returns its duration or None if idle."""
        batch = self._begin_pass(now)
        if batch is None:
            return None
        dp_tokens = [sum(t for _, t in batch.get(d, [])) for d in self.dp_ids]
        return self.cost.prefill_pass_time(dp_tokens, chunk=self.chunk)

    def finish_pass(self, now: float) -> PassResult:
        assert self.busy and self._current is not None
        evs: List[EndForward] = []
        completed: List[Request] = []
        processed: Dict[int, int] = {}
        for d in self.dp_ids:
            taken = self._current.get(d, [])
            ptok = sum(t for _, t in taken)
            processed[d] = ptok
            self.tokens_processed += ptok
            for req, tok in taken:
                req.inflight -= tok
                if req.inflight == 0 and req.remaining_prefill == 0:
                    req.first_token_time = now
                    completed.append(req)
            evs.append(EndForward(
                instance_id=self.instance_id, dp_id=d,
                exec_time=0.0,                    # filled by the sim
                processed_tokens=ptok,
                remaining_tokens=self.backlog(d),
                timestamp=now))
        self._current = None
        self.busy = False
        return PassResult(evs, completed, processed)

    @property
    def chunk_utilization(self) -> float:
        if self.capacity_offered == 0:
            return 0.0
        return self.tokens_processed / self.capacity_offered


class SimDecodeInstance(DecodeEngine):
    """Decode instance: DP units step together behind the sync barrier."""

    def __init__(self, instance_id: int, dp_ids: Sequence[int],
                 cost: Optional[CostModel]):
        self.instance_id = instance_id
        self.dp_ids = list(dp_ids)
        self.cost = cost
        self.running: Dict[int, List[Request]] = {d: [] for d in dp_ids}
        self.busy = False
        self.tokens_generated = 0
        self.steps = 0
        self.epoch = 0      # bumped on drain(); invalidates in-flight steps
        # inter-token latency samples (gap between consecutive emissions
        # of one request on THIS engine) — the tentpole metric of the
        # unified plane: a decode stall behind a prefill pass shows up
        # here as a fat p99
        self.itl: List[float] = []
        self._last_emit: Dict[int, float] = {}

    def _record_emit(self, rid: int, now: float) -> None:
        last = self._last_emit.get(rid)
        if last is not None:
            self.itl.append(now - last)
        self._last_emit[rid] = now

    def admit(self, dp_id: int, req: Request) -> None:
        self.running[dp_id].append(req)

    def has_work(self) -> bool:
        return any(self.running[d] for d in self.dp_ids)

    def drain(self) -> Dict[int, List[Request]]:
        """Watchdog re-dispatch: strip all running work off this instance
        (it is presumed wedged) and unlock it. The caller owns releasing
        the per-DP KV accounting and re-placing the requests."""
        out = {d: reqs for d, reqs in self.running.items() if reqs}
        self.running = {d: [] for d in self.dp_ids}
        self.busy = False
        self.epoch += 1     # any step_end still in flight is now stale
        return out

    def preempt(self, rid: int) -> Optional[Request]:
        """Page-level preemption, per victim (the drain() mechanics at
        request granularity): remove one resident request so its KV can
        be parked and re-admitted later through the normal join path.
        The caller owns releasing the DecodeDPState accounting.  Refused
        (None) while a step is in flight — a swap must never race the
        instance barrier."""
        if self.busy:
            return None
        for d in self.dp_ids:
            for r in self.running[d]:
                if r.rid == rid:
                    self.running[d].remove(r)
                    return r
        return None

    def _target_len(self, req: Request) -> int:
        """Tokens at which `req` is finished (real plane may cap this)."""
        return req.output_len

    def start_step(self, dp_states, now: Optional[float] = None
                   ) -> StartResult:
        if self.busy or not self.has_work():
            return None
        self.busy = True
        by_id = {s.dp_id: s for s in dp_states}
        batches = [len(self.running[d]) for d in self.dp_ids]
        # kv_occupancy: paged units are priced at block granularity
        # (reserved pages are resident and swept every step), so the sim
        # plane models the same fragmentation the real paged engine pays
        kvs = [by_id[d].kv_occupancy for d in self.dp_ids]
        self.steps += 1
        return self.cost.decode_step_time(batches, kvs)

    def finish_step(self, now: float, dp_states) -> List[Request]:
        """Each running request emits one token; returns finished requests."""
        assert self.busy
        self.busy = False
        by_id = {s.dp_id: s for s in dp_states}
        finished: List[Request] = []
        for d in self.dp_ids:
            alive: List[Request] = []
            st = by_id[d]
            n = len(self.running[d])
            if n:
                st.step(n)                      # K_i += participants
                self.tokens_generated += n
            for req in self.running[d]:
                req.generated += 1
                if req.first_token_time is None:
                    req.first_token_time = now
                self._record_emit(req.rid, now)
                if req.generated >= self._target_len(req):
                    req.finish_time = now
                    st.release(req.input_len + req.generated,
                               reserve_len=req.input_len + req.output_len)
                    self._last_emit.pop(req.rid, None)
                    finished.append(req)
                else:
                    alive.append(req)
            self.running[d] = alive
        return finished

class SimUnifiedInstance(SimDecodeInstance, UnifiedEngine):
    """Unified mixed-batch instance (the Sarathi-style piggyback plane,
    cost-model clocked).  Prompts are admitted RAW (remaining_prefill >
    0) to the decode plane; each step carries the decode rows plus as
    many pending prefill-chunk tokens as fit the leftover token budget
    (`chunk − decode_rows`), priced by `CostModel.mixed_step_time` —
    decode rows keep emitting every step, so prefill no longer stalls
    them.

    Starvation bound: when decode rows alone exhaust the budget for
    `starve_limit` consecutive steps while prefill is pending, the next
    step grants a minimum chunk (`chunk // 4`) anyway, so prefill can
    lag but never be locked out.

    `piggyback=False` is the DISJOINT ablation (the A/B baseline — the
    prefill-prioritizing chunked loop Sarathi measures against): a step
    with pending prefill runs ONLY the prefill chunk and the decode rows
    stall through it, which is exactly the ITL-p99 bubble the unified
    plane removes."""

    def __init__(self, instance_id: int, dp_ids: Sequence[int],
                 cost: Optional[CostModel], chunk: int = 3072,
                 starve_limit: int = 4, piggyback: bool = True):
        super().__init__(instance_id, dp_ids, cost)
        self.chunk = max(int(chunk), 1)
        self.starve_limit = max(int(starve_limit), 1)
        self.piggyback = piggyback
        self.prefilling: Dict[int, Deque[Request]] = {
            d: collections.deque() for d in dp_ids}
        self._starve: Dict[int, int] = {d: 0 for d in dp_ids}
        self._grants: Dict[int, List[Tuple[Request, int]]] = {}
        self._stalled: set = set()
        self.prefill_tokens = 0
        self.forced_grants = 0      # starvation-bound activations

    # ------------------------------------------------------------------
    def admit(self, dp_id: int, req: Request) -> None:
        if req.remaining_prefill > 0:
            self.prefilling[dp_id].append(req)
        else:
            super().admit(dp_id, req)

    def has_work(self) -> bool:
        return (super().has_work()
                or any(self.prefilling[d] for d in self.dp_ids))

    def prefill_backlog(self) -> int:
        return sum(r.remaining_prefill for d in self.dp_ids
                   for r in self.prefilling[d])

    def drain(self) -> Dict[int, List[Request]]:
        out = super().drain()
        for d in self.dp_ids:
            if self.prefilling[d]:
                out.setdefault(d, []).extend(self.prefilling[d])
                self.prefilling[d].clear()
            self._starve[d] = 0
        self._grants = {}
        self._stalled = set()
        return out

    def preempt(self, rid: int) -> Optional[Request]:
        got = super().preempt(rid)
        if got is not None or self.busy:
            return got
        for d in self.dp_ids:
            for r in self.prefilling[d]:
                if r.rid == rid:
                    self.prefilling[d].remove(r)
                    return r
        return None

    # ------------------------------------------------------------------
    def _form_grants(self, d: int, n_decode: int, now: float
                     ) -> List[Tuple[Request, int]]:
        """Fill the leftover token budget of DP `d` with pending prefill
        chunks (FIFO).  Queue state is NOT mutated here — completions
        are applied in finish_step, so an epoch-invalidating drain
        mid-step loses nothing."""
        q = self.prefilling[d]
        if not q:
            self._starve[d] = 0
            return []
        # disjoint ablation: prefill-prioritizing baseline — the full
        # chunk budget every step, decode rows stall while it runs
        budget = self.chunk - n_decode if self.piggyback else self.chunk
        if budget <= 0:
            self._starve[d] += 1
            if self._starve[d] < self.starve_limit:
                return []
            budget = max(1, self.chunk // 4)    # forced minimum grant
            self.forced_grants += 1
        grants: List[Tuple[Request, int]] = []
        for req in q:
            if budget <= 0:
                break
            use = min(req.remaining_prefill, budget)
            if req.prefill_start is None:
                req.prefill_start = now
            grants.append((req, use))
            budget -= use
        if grants:
            self._starve[d] = 0
        return grants

    def start_step(self, dp_states, now: Optional[float] = None
                   ) -> StartResult:
        if self.busy or not self.has_work():
            return None
        by_id = {s.dp_id: s for s in dp_states}
        self._grants = {}
        self._stalled = set()
        batches: List[int] = []
        kvs: List[int] = []
        ptoks: List[int] = []
        for d in self.dp_ids:
            n = len(self.running[d])
            grants = self._form_grants(d, n, now if now is not None else 0.0)
            p = sum(t for _, t in grants)
            if grants:
                self._grants[d] = grants
            if grants and not self.piggyback and n:
                # disjoint forced-prefill step: decode rows stall
                self._stalled.add(d)
                batches.append(0)
                kvs.append(0)
            else:
                batches.append(n)
                kvs.append(by_id[d].kv_occupancy if n else 0)
            ptoks.append(p)
        self.busy = True
        self.steps += 1
        return self.cost.mixed_step_time(batches, kvs, ptoks)

    def finish_step(self, now: float, dp_states) -> List[Request]:
        grants = self._grants
        stalled = self._stalled
        self._grants = {}
        self._stalled = set()
        by_id = {s.dp_id: s for s in dp_states}
        # decode half: stalled DPs (disjoint forced-prefill steps) emit
        # nothing — detach their rows so the parent pass skips them
        saved = {d: self.running[d] for d in stalled}
        for d in stalled:
            self.running[d] = []
        finished = super().finish_step(now, dp_states)
        for d, rows in saved.items():
            self.running[d] = rows + self.running[d]
        # prefill half: apply granted chunk tokens; a completed prompt
        # emits its first token (argmax of the chunk's last position on
        # the real plane) and graduates to the decode rows
        for d, lst in grants.items():
            st = by_id[d]
            q = self.prefilling[d]
            for req, use in lst:
                req.remaining_prefill -= use
                self.prefill_tokens += use
                if req.remaining_prefill > 0:
                    continue
                q.remove(req)
                st.step(1)          # the emitted token's KV entry
                req.generated += 1
                if req.first_token_time is None:
                    req.first_token_time = now
                self._record_emit(req.rid, now)
                if req.generated >= self._target_len(req):
                    req.finish_time = now
                    st.release(req.input_len + req.generated,
                               reserve_len=req.input_len + req.output_len)
                    self._last_emit.pop(req.rid, None)
                    finished.append(req)
                else:
                    self.running[d].append(req)
        return finished
