"""Algorithm 3 — IQR-Aware Lexicographical Decode Scheduling.

Step 1 (Mask): DP units whose KV load exceeds Q3 + k·IQR are outliers —
masked out of the decision space (fallback: all units if everything is
saturated).
Step 2 (Lexicographical select): among safe units pick
argmin ⟨B_i, K_i⟩ — batch size first (parallel efficiency), KV load as the
tie-breaker (memory pressure).
Step 3: assign and update state.

Requests are pre-sorted by total sequence length descending
("fill-the-valley": place heavy requests while the decision space is rich).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.types import DecodeDPState, Request

# cache-aware placement hook: affinity(req, unit) -> matched prefix tokens
# already resident on that unit (0 = no preference)
AffinityFn = Callable[[Request, DecodeDPState], int]


def _best_affinity(req: Request, units: Sequence[DecodeDPState],
                   affinity: Optional[AffinityFn]
                   ) -> Optional[DecodeDPState]:
    """Cache-aware placement: among `units`, the one holding the longest
    cached prefix of `req` — ties broken by least ⟨kv_occupancy, batch⟩
    so reuse never concentrates load on one hot unit.  None when no unit
    holds any prefix (fall through to the load-based policy)."""
    if affinity is None:
        return None
    scored = [(affinity(req, u), u) for u in units]
    best_hit = max(s for s, _ in scored)
    if best_hit <= 0:
        return None
    cands = [u for s, u in scored if s == best_hit]
    return min(cands, key=lambda u: (u.kv_occupancy, u.batch))


def percentile(values: Sequence[float], q: float) -> float:
    """Linear-interpolation percentile (numpy 'linear' method)."""
    if not values:
        raise ValueError("empty")
    v = sorted(values)
    if len(v) == 1:
        return float(v[0])
    rank = (len(v) - 1) * q / 100.0
    lo = int(rank)
    hi = min(lo + 1, len(v) - 1)
    frac = rank - lo
    return float(v[lo] * (1 - frac) + v[hi] * frac)


def iqr_safe_set(units: Sequence[DecodeDPState], k: float = 1.5
                 ) -> List[DecodeDPState]:
    """Step 1 — outlier detection over the KV-load snapshot.  The load
    metric is `kv_occupancy`: identical to kv_tokens on padded units, and
    block-granular (reserved pages, fragmentation included) on paged
    units, so the mask sees what the device memory actually holds."""
    kv = [u.kv_occupancy for u in units]
    q1, q3 = percentile(kv, 25), percentile(kv, 75)
    th = q3 + k * (q3 - q1)
    safe = [u for u in units if u.kv_occupancy <= th]
    # hard budgets also mask (memory exhaustion risk)
    safe = [u for u in safe
            if u.batch < u.max_batch and u.kv_occupancy < u.kv_budget]
    if not safe:
        safe = list(units)      # fallback: all saturated
    return safe


def lex_compare(a: DecodeDPState, b: DecodeDPState) -> bool:
    """LexCompare(i, j): (B_i < B_j) or (B_i == B_j and K_i < K_j)."""
    return (a.batch < b.batch) or (a.batch == b.batch
                                   and a.kv_tokens < b.kv_tokens)


def schedule_decode_batch(
    requests: Sequence[Request],
    units: Sequence[DecodeDPState],
    k: float = 1.5,
) -> Dict[int, List[Request]]:
    """ScheduleBatch(R, N) — returns dp_id -> assigned requests and updates
    unit states in place."""
    out: Dict[int, List[Request]] = {}
    # Length-Based Pre-Sorting (fill-the-valley); priority classes place
    # first, so urgent work sees the richest decision space
    order = sorted(requests,
                   key=lambda r: (r.priority, -(r.input_len + r.output_len)))
    for req in order:
        safe = iqr_safe_set(units, k)
        best: Optional[DecodeDPState] = None
        for u in safe:
            if best is None or lex_compare(u, best):
                best = u
        assert best is not None
        best.admit(req.input_len + req.generated,
                   reserve_len=req.input_len + req.output_len)
        req.assigned_dp = best.dp_id
        out.setdefault(best.dp_id, []).append(req)
    return out


# ---------------------------------------------------------------------------
# Load-Aware Global Allocation (decode phase, two-level)
# ---------------------------------------------------------------------------

def schedule_decode_global(
    requests: Sequence[Request],
    units: Sequence[DecodeDPState],
    k: float = 1.5,
    exclude_instances: frozenset = frozenset(),
    affinity: Optional[AffinityFn] = None,
) -> Dict[int, List[Request]]:
    """Batched decode placement that balances per-DP KV-TOKEN load (not
    just request count) across DP units within an instance AND across
    instances.

    Level 1 picks the target instance by least mean-per-unit ⟨K, B⟩ load,
    so a hot instance sheds traffic to its peers; level 2 picks the DP
    within it by least ⟨K_i, B_i⟩ — KV load first, batch as tie-break
    (the dual of Algorithm 3's batch-first order, for memory-bound decode
    pools).  IQR masking and hard budgets apply over the global DP
    population exactly as in `iqr_safe_set`.  `exclude_instances` removes
    quarantined (watchdog-expired) instances from the decision space; if
    that empties it, the exclusion is ignored rather than dropping work.

    `affinity`, when given, is the cache-aware override (§context
    caching): a safe unit already holding a prefix of the request wins
    over the load order — joining there points at resident pages instead
    of re-copying KV, and a longer match beats a shorter one.  Load-based
    placement is the tie-break and the fallback when nothing matches.
    """
    eligible = [u for u in units if u.instance_id not in exclude_instances]
    if not eligible:
        eligible = list(units)
    all_of: Dict[int, List[DecodeDPState]] = {}
    for u in eligible:
        all_of.setdefault(u.instance_id, []).append(u)
    out: Dict[int, List[Request]] = {}
    order = sorted(requests,
                   key=lambda r: (r.priority, -(r.input_len + r.output_len)))
    for req in order:
        safe = iqr_safe_set(eligible, k)
        best = _best_affinity(req, safe, affinity)
        if best is None:
            by_inst: Dict[int, List[DecodeDPState]] = {}
            for u in safe:
                by_inst.setdefault(u.instance_id, []).append(u)
            # level-1 load is the mean over ALL the instance's units —
            # masked (saturated) units still pace its sync barrier, so
            # hiding them would make a hot instance look cold and attract
            # traffic.  Loads are kv_occupancy so paged fragmentation is
            # balanced, not hidden.
            inst = min(by_inst, key=lambda i: (
                sum(u.kv_occupancy for u in all_of[i]) / len(all_of[i]),
                sum(u.batch for u in all_of[i]) / len(all_of[i])))
            best = min(by_inst[inst],
                       key=lambda u: (u.kv_occupancy, u.batch))
        best.admit(req.input_len + req.generated,
                   reserve_len=req.input_len + req.output_len)
        req.assigned_dp = best.dp_id
        out.setdefault(best.dp_id, []).append(req)
    return out


# ---------------------------------------------------------------------------
# Page-level preemption — victim selection (SLO-aware overload control)
# ---------------------------------------------------------------------------

def kv_footprint(req: Request, block_size: int) -> int:
    """KV tokens a resident request's reservation holds: its lifetime
    (input + output) rounded up to whole blocks when paged — the same
    ceiling rule admission reserved by, so preempting the victim frees
    exactly this much headroom."""
    total = req.input_len + req.output_len
    if not block_size:
        return total
    from repro.core.types import blocks_for_tokens
    return blocks_for_tokens(total, block_size) * block_size


def select_victims(
    residents: Sequence[Request],
    need_tokens: int,
    block_size: int = 0,
    max_priority: Optional[int] = None,
) -> List[Request]:
    """Pick the requests to swap out to free >= `need_tokens` of KV.

    Policy (one model for the sim and real planes): only requests of
    priority STRICTLY LOWER than `max_priority` are eligible (a waiter
    can never evict its own class or better — the strict ordering is
    what makes preemption cycle-free); among eligible residents the
    least-urgent class goes first, ties broken by least generation
    progress (cheapest swap payload, most remaining work to benefit from
    re-placement) then youngest arrival (preserve FCFS within a class).
    Victims accumulate until their reservations cover the need; returns
    [] when the eligible set cannot cover it (partial preemption would
    burn swaps without admitting the waiter)."""
    if need_tokens <= 0:
        return []
    elig = [r for r in residents
            if max_priority is None or r.priority > max_priority]
    elig.sort(key=lambda r: (-r.priority, r.generated, -r.arrival_time))
    out: List[Request] = []
    freed = 0
    for r in elig:
        out.append(r)
        freed += kv_footprint(r, block_size)
        if freed >= need_tokens:
            return out
    return []


# ---------------------------------------------------------------------------
# Immediate-dispatch decode baselines (paper's comparison point)
# ---------------------------------------------------------------------------

def schedule_decode_immediate(
    requests: Sequence[Request],
    units: Sequence[DecodeDPState],
    policy: str = "round_robin",
    rr_state: Optional[List[int]] = None,
    affinity: Optional[AffinityFn] = None,
) -> Dict[int, List[Request]]:
    """Baselines: round_robin | least_batch | least_kv. No global window —
    each request is placed in arrival order with instantaneous state only.
    `affinity` adds cache-aware placement on top: a unit holding a cached
    prefix wins outright (round-robin state does NOT advance for such a
    request — the rotation resumes where it left off)."""
    out: Dict[int, List[Request]] = {}
    for req in requests:
        u = _best_affinity(req, units, affinity)
        if u is not None:
            u.admit(req.input_len + req.generated,
                    reserve_len=req.input_len + req.output_len)
            req.assigned_dp = u.dp_id
            out.setdefault(u.dp_id, []).append(req)
            continue
        if policy == "round_robin":
            assert rr_state is not None
            u = units[rr_state[0] % len(units)]
            rr_state[0] += 1
        elif policy == "least_batch":
            u = min(units, key=lambda x: x.batch)
        elif policy == "least_kv":
            # occupancy, like every batched allocator above: the baseline
            # must not be blind to paged block reservations
            u = min(units, key=lambda x: x.kv_occupancy)
        else:
            raise ValueError(policy)
        u.admit(req.input_len + req.generated,
                reserve_len=req.input_len + req.output_len)
        req.assigned_dp = u.dp_id
        out.setdefault(u.dp_id, []).append(req)
    return out
