"""Radix-tree prefix-cache index for cache-aware PBAA (§4.2.2).

The scheduler keeps one radix tree PER DP UNIT (KV caches are DP-local in
DP+EP systems). `match` returns the longest cached prefix length; `insert`
records a processed prefix; LRU eviction under a token budget.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple


class _Node:
    __slots__ = ("edges", "last_used", "tokens")

    def __init__(self):
        self.edges: Dict[Tuple[int, ...], "_Node"] = {}
        self.last_used = 0.0
        self.tokens = 0   # tokens on the edge INTO this node


class RadixTree:
    """Compressed trie over token sequences with LRU eviction."""

    def __init__(self, budget_tokens: int = 1_000_000, block: int = 16):
        self.root = _Node()
        self.budget = budget_tokens
        self.block = block           # match granularity (KV block size)
        self.size = 0
        self._clock = 0.0

    def _tick(self) -> float:
        self._clock += 1.0
        return self._clock

    def _blocks(self, tokens: Sequence[int]) -> List[Tuple[int, ...]]:
        t = tuple(tokens)
        return [t[i:i + self.block] for i in range(0, len(t), self.block)]

    def match(self, tokens: Sequence[int]) -> int:
        """Longest cached prefix (in tokens, block-quantized)."""
        if not tokens:
            return 0
        now = self._tick()
        node, matched = self.root, 0
        for blk in self._blocks(tokens):
            nxt = node.edges.get(blk)
            if nxt is None:
                break
            node, matched = nxt, matched + len(blk)
            node.last_used = now
        return matched

    def insert(self, tokens: Sequence[int]) -> int:
        """Insert prefix; returns newly added token count."""
        now = self._tick()
        node, added = self.root, 0
        for blk in self._blocks(tokens):
            nxt = node.edges.get(blk)
            if nxt is None:
                nxt = _Node()
                nxt.tokens = len(blk)
                node.edges[blk] = nxt
                added += len(blk)
            nxt.last_used = now
            node = nxt
        self.size += added
        if self.size > self.budget:
            self._evict(self.size - self.budget)
        return added

    def _evict(self, need: int) -> None:
        """Evict least-recently-used leaves until `need` tokens are freed."""
        freed = 0
        while freed < need:
            leaf = self._lru_leaf(self.root, None, None)
            if leaf is None:
                break
            parent, key, node = leaf
            parent.edges.pop(key)
            freed += node.tokens
        self.size -= freed

    def _lru_leaf(self, node: "_Node", parent, key):
        best = None
        for k, child in node.edges.items():
            if not child.edges:   # leaf
                cand = (node, k, child)
                if best is None or cand[2].last_used < best[2].last_used:
                    best = cand
            else:
                cand = self._lru_leaf(child, node, k)
                if cand is not None and (
                        best is None or cand[2].last_used < best[2].last_used):
                    best = cand
        return best


class PrefixCacheIndex:
    """Per-DP radix trees, the scheduler-side model of engine KV reuse."""

    def __init__(self, dp_ids: Sequence[int], budget_tokens: int = 1_000_000,
                 block: int = 16):
        self.trees: Dict[int, RadixTree] = {
            d: RadixTree(budget_tokens, block) for d in dp_ids}

    def match(self, dp_id: int, tokens: Optional[Sequence[int]],
              limit: Optional[int] = None) -> int:
        if tokens is None or dp_id not in self.trees:
            return 0
        m = self.trees[dp_id].match(tokens)
        return min(m, limit) if limit is not None else m

    def insert(self, dp_id: int, tokens: Optional[Sequence[int]]) -> int:
        if tokens is None or dp_id not in self.trees:
            return 0
        return self.trees[dp_id].insert(tokens)
