"""Radix-tree prefix-cache index for cache-aware PBAA (§4.2.2).

The scheduler keeps one radix tree PER DP UNIT (KV caches are DP-local in
DP+EP systems). `match` returns the longest cached prefix length; `insert`
records a processed prefix; LRU eviction under a token budget.

Nodes can optionally be BOUND to physical KV block ids (the real plane's
`BlockPool` pages, see `serving/page_share.py`): a bound node means "this
edge's tokens live in these pages", so admission resolves a request's
longest cached prefix to real memory instead of recomputing it.  Eviction
then hands the evicted nodes' blocks to an `on_evict` callback, which
drops the tree's reference — the pool only reclaims a page once every
holder (tree AND in-flight block tables) has let go, so LRU pressure can
never free a block that is still referenced.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple


class _Node:
    __slots__ = ("edges", "last_used", "tokens", "blocks", "value")

    def __init__(self):
        self.edges: Dict[Tuple[int, ...], "_Node"] = {}
        self.last_used = 0.0
        self.tokens = 0   # tokens on the edge INTO this node
        # physical KV block ids holding this edge's tokens (page binding);
        # empty for scheduler-side (simulated) trees
        self.blocks: Tuple[int, ...] = ()
        # terminal payload for an exact full-sequence hit (the real plane
        # stores the argmax first token so a full-prefix hit can skip
        # prefill compute entirely)
        self.value = None


class RadixTree:
    """Compressed trie over token sequences with LRU eviction."""

    def __init__(self, budget_tokens: int = 1_000_000, block: int = 16,
                 on_evict: Optional[Callable[["_Node"], None]] = None):
        self.root = _Node()
        self.budget = budget_tokens
        self.block = block           # match granularity (KV block size)
        self.size = 0
        self._clock = 0.0
        self._on_evict = on_evict

    def _tick(self) -> float:
        self._clock += 1.0
        return self._clock

    def _blocks(self, tokens: Sequence[int]) -> List[Tuple[int, ...]]:
        t = tuple(tokens)
        return [t[i:i + self.block] for i in range(0, len(t), self.block)]

    def _walk(self, tokens: Sequence[int]) -> Tuple[int, List["_Node"]]:
        """Descend as far as the cached edges allow; returns the matched
        token count and the matched path (root excluded)."""
        node, matched, path = self.root, 0, []
        for blk in self._blocks(tokens):
            nxt = node.edges.get(blk)
            if nxt is None:
                break
            node, matched = nxt, matched + len(blk)
            path.append(nxt)
        return matched, path

    def _bump(self, path: Sequence["_Node"]) -> None:
        """Refresh `last_used` on a node AND every ancestor on its path:
        a hot child must keep its parent edges warm, otherwise LRU
        pressure could peel the parent chain out from under a prefix
        that is still being matched."""
        now = self._tick()
        for n in path:
            n.last_used = now

    def match(self, tokens: Sequence[int]) -> int:
        """Longest cached prefix (in tokens, block-quantized)."""
        if not tokens:
            return 0
        matched, path = self._walk(tokens)
        self._bump(path)
        return matched

    def match_path(self, tokens: Sequence[int]
                   ) -> Tuple[int, List["_Node"]]:
        """Like `match` but also returns the matched nodes, so a page
        binder can read their bound block ids / terminal payload."""
        if not tokens:
            return 0, []
        matched, path = self._walk(tokens)
        self._bump(path)
        return matched, path

    def insert(self, tokens: Sequence[int],
               blocks: Optional[Sequence[Sequence[int]]] = None,
               value=None) -> int:
        """Insert prefix; returns newly added token count.

        `blocks`, when given, is one id-tuple per `block`-sized edge of
        `tokens` (parallel to the descent) and binds each node to the
        physical pages holding its edge — nodes that already carry a
        binding keep it (first copy wins).  `value` is attached to the
        terminal node (exact-sequence payload).
        """
        now = self._tick()
        node, added = self.root, 0
        for i, blk in enumerate(self._blocks(tokens)):
            nxt = node.edges.get(blk)
            if nxt is None:
                nxt = _Node()
                nxt.tokens = len(blk)
                node.edges[blk] = nxt
                added += len(blk)
            if blocks is not None and i < len(blocks) and not nxt.blocks:
                nxt.blocks = tuple(blocks[i])
            nxt.last_used = now
            node = nxt
        if value is not None:
            node.value = value
        self.size += added
        if self.size > self.budget:
            self._evict(self.size - self.budget)
        return added

    def evict_tokens(self, need: int) -> int:
        """Externally-driven LRU eviction (pool pressure): free at least
        `need` cached tokens, returning the count actually evicted."""
        before = self.size
        self._evict(need)
        return before - self.size

    def _evict(self, need: int) -> None:
        """Evict least-recently-used leaves until `need` tokens are freed.
        Bound blocks are released through `on_evict` — a decref, not a
        force-free, so pages shared with live block tables survive."""
        freed = 0
        while freed < need:
            leaf = self._lru_leaf(self.root, None, None)
            if leaf is None:
                break
            parent, key, node = leaf
            parent.edges.pop(key)
            freed += node.tokens
            if self._on_evict is not None:
                self._on_evict(node)
        self.size -= freed

    def _lru_leaf(self, node: "_Node", parent, key):
        best = None
        for k, child in node.edges.items():
            if not child.edges:   # leaf
                cand = (node, k, child)
                if best is None or cand[2].last_used < best[2].last_used:
                    best = cand
            else:
                cand = self._lru_leaf(child, node, k)
                if cand is not None and (
                        best is None or cand[2].last_used < best[2].last_used):
                    best = cand
        return best


class PrefixCacheIndex:
    """Per-DP radix trees, the scheduler-side model of engine KV reuse.

    Also keeps the hit accounting the benchmark harness reads:
    `hit_tokens` / `seen_tokens` accumulate per first-dispatch request
    (see `prefill_alloc.greedy_dispatch`), so `hit_rate` is the fraction
    of prompt tokens served from cache."""

    def __init__(self, dp_ids: Sequence[int], budget_tokens: int = 1_000_000,
                 block: int = 16):
        self.trees: Dict[int, RadixTree] = {
            d: RadixTree(budget_tokens, block) for d in dp_ids}
        self.hit_tokens = 0
        self.seen_tokens = 0

    def match(self, dp_id: int, tokens: Optional[Sequence[int]],
              limit: Optional[int] = None) -> int:
        if tokens is None or dp_id not in self.trees:
            return 0
        m = self.trees[dp_id].match(tokens)
        return min(m, limit) if limit is not None else m

    def insert(self, dp_id: int, tokens: Optional[Sequence[int]]) -> int:
        if tokens is None or dp_id not in self.trees:
            return 0
        return self.trees[dp_id].insert(tokens)

    def record(self, hit: int, prompt: int) -> None:
        """Account one request's first dispatch: `hit` of `prompt` prompt
        tokens were served from cache."""
        self.hit_tokens += hit
        self.seen_tokens += prompt

    @property
    def hit_rate(self) -> float:
        return self.hit_tokens / self.seen_tokens if self.seen_tokens else 0.0
