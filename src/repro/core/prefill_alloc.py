"""Algorithm 2 — Prioritized Batch Allocation Algorithm (PBAA).

Three phases:
  1. Starvation prevention — requests left over from previous cycles go first
     (strict FCFS across cycles).
  2. Straggler-aware bin packing — longest request → DP with max C_avail
     ("water-filling"), optionally cache-aware
     (effective cost = L(r) − L_hit(r, d)).
  3. Overload detection — requests unassigned for > N_limit cycles trigger
     flow control.

Chunked-prefill semantics: a request longer than the remaining chunk capacity
is SPLIT — the head chunk is dispatched, the tail stays in `remaining` for
the next cycle. This is the fine-grained (chunk-level) capacity model of
§4.2.1 that lifts Chunk Utilization from ~52% to ~88%.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.types import DPState, Request
from repro.core.prefix_cache import PrefixCacheIndex


def _cache_hit(req: Request, dp: DPState,
               cache: Optional[PrefixCacheIndex]) -> int:
    if cache is None or req.tokens is None:
        return 0
    if getattr(cache, "first_dispatch_only", False) and (
            req.assigned_dp is not None):
        # engine-backed index (real plane): the hit was CLAIMED as live
        # pages at first dispatch — later chunks of a pinned request must
        # not be re-credited against pages it already points at
        return 0
    return cache.match(dp.dp_id, req.tokens, limit=req.remaining_prefill)


def greedy_dispatch(
    queue: Sequence[Request],
    dps: Sequence[DPState],
    assignments: Dict[int, List[Tuple[Request, int]]],
    cache: Optional[PrefixCacheIndex] = None,
    allow_chunking: bool = True,
) -> List[Request]:
    """GreedyDispatch(Q) of Algorithm 2. Records grants in `assignments`;
    returns requests that did not (fully) fit.

    A request whose earlier chunk already ran on DP d is PINNED to d — its
    KV cache lives there. Cache-aware mode credits the prefix-cache hit
    length against the capacity cost (§4.2.2 'Optimization for Context
    Caching')."""
    leftovers: List[Request] = []
    # line 2: sort by length descending (reduce fragmentation); priority
    # classes cut first — an interactive request is granted chunk
    # capacity before any longer batch request (with uniform priorities
    # this is exactly the paper's length order)
    order = sorted(queue, key=lambda r: (r.priority, -r.remaining_prefill))
    avail = {d.dp_id: d.c_avail for d in dps}
    for req in order:
        if req.assigned_dp is not None:
            cands = [d for d in dps if d.dp_id == req.assigned_dp]
        else:
            cands = dps
        # line 6: d* = argmax Capacity(r, d)  (Basic / Cache-Aware modes)
        best, best_cap, best_hit = None, None, 0
        for d in cands:
            hit = _cache_hit(req, d, cache)
            cap = avail[d.dp_id] - (req.remaining_prefill - hit)
            if best_cap is None or cap > best_cap:
                best, best_cap, best_hit = d, cap, hit
        # line 8: dispatch only if the target still has headroom
        if best is not None and avail[best.dp_id] > 0:
            if cache is not None and req.assigned_dp is None:
                # hit-rate accounting, once per request at first grant
                cache.record(best_hit, req.remaining_prefill)
            cost = req.remaining_prefill - best_hit
            grant = min(cost, avail[best.dp_id]) if allow_chunking else cost
            assignments.setdefault(best.dp_id, []).append((req, grant))
            avail[best.dp_id] -= grant
            req.remaining_prefill -= grant + best_hit
            req.assigned_dp = best.dp_id
            if req.remaining_prefill > 0:
                leftovers.append(req)      # tail chunk re-queues
        else:
            leftovers.append(req)
    return leftovers


def pbaa(
    pending: Sequence[Request],
    new: Sequence[Request],
    dps: Sequence[DPState],
    n_limit: int = 8,
    cache: Optional[PrefixCacheIndex] = None,
    allow_chunking: bool = True,
) -> Tuple[Dict[int, List[Tuple[Request, int]]], List[Request], List[Request]]:
    """Full Algorithm 2. Returns (assignment map, next-cycle queue,
    flow-controlled requests)."""
    assignments: Dict[int, List[Tuple[Request, int]]] = {}
    # Phase 1: prioritize legacy
    left_pending = greedy_dispatch(pending, dps, assignments, cache,
                                   allow_chunking)
    # account pending-phase grants before the new-arrival phase
    _apply_inflight(dps, assignments)
    # Phase 2: new arrivals
    assignments2: Dict[int, List[Tuple[Request, int]]] = {}
    left_new = greedy_dispatch(new, dps, assignments2, cache, allow_chunking)
    _apply_inflight(dps, assignments2)
    for k, v in assignments2.items():
        assignments.setdefault(k, []).extend(v)
    # Phase 3: overload detection
    q_next: List[Request] = []
    throttled: List[Request] = []
    for r in left_pending + left_new:
        r.wait_cycles += 1
        if r.wait_cycles > n_limit:
            throttled.append(r)            # FlowControl(Throttle/Reject)
        else:
            q_next.append(r)
    return assignments, q_next, throttled


def _apply_inflight(dps: Sequence[DPState],
                    assignments: Dict[int, List[Tuple[Request, int]]]) -> None:
    by_id = {d.dp_id: d for d in dps}
    for dp_id, lst in assignments.items():
        for _, tok in lst:
            by_id[dp_id].on_dispatch(tok)


def chunk_utilization(
    assignments: Dict[int, List[Tuple[Request, int]]],
    dps: Sequence[DPState],
) -> float:
    """Fraction of theoretical chunk capacity filled this cycle (Table 1)."""
    cap = sum(d.c_chunk for d in dps)
    used = sum(t for lst in assignments.values() for _, t in lst)
    return used / cap if cap else 0.0
