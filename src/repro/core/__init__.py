"""The paper's primary contribution: Staggered Batch Scheduling.

interval.py       — Algorithm 1 (throughput-adaptive interval control)
prefill_alloc.py  — Algorithm 2 (PBAA water-filling bin packing)
decode_alloc.py   — Algorithm 3 (IQR-aware lexicographical decode scheduling)
sync.py           — §4.1.2 multi-tier state-synchronization protocol
scheduler.py      — SBS main loop + immediate-dispatch baselines
state.py          — global state matrix ⟨C_avail, B_i, K_i⟩
prefix_cache.py   — radix-tree index for cache-aware PBAA
flow_control.py   — overload protection
"""

from repro.core.interval import AdaptiveIntervalController
from repro.core.prefill_alloc import greedy_dispatch, pbaa, chunk_utilization
from repro.core.decode_alloc import (
    iqr_safe_set, lex_compare, schedule_decode_batch,
    schedule_decode_immediate,
)
from repro.core.scheduler import (
    StaggeredBatchScheduler, ImmediatePrefillScheduler, DecodeScheduler,
)
from repro.core.state import GlobalState
from repro.core.sync import SyncProtocol, Readiness
from repro.core.types import (
    DecodeDPState, DPState, DispatchCommand, EndForward, Request,
    RequestPhase,
)
from repro.core.prefix_cache import PrefixCacheIndex, RadixTree
from repro.core.flow_control import FlowAction, FlowController

__all__ = [
    "AdaptiveIntervalController", "greedy_dispatch", "pbaa",
    "chunk_utilization", "iqr_safe_set", "lex_compare",
    "schedule_decode_batch", "schedule_decode_immediate",
    "StaggeredBatchScheduler", "ImmediatePrefillScheduler", "DecodeScheduler",
    "GlobalState", "SyncProtocol", "Readiness", "DecodeDPState", "DPState",
    "DispatchCommand", "EndForward", "Request", "RequestPhase",
    "PrefixCacheIndex", "RadixTree", "FlowAction", "FlowController",
]
