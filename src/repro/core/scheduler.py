"""The Staggered Batch Scheduler (SBS) main loop + immediate-dispatch
baselines (paper §4, Figure 5).

The scheduler is CLOCK-DRIVEN and ENGINE-AGNOSTIC: the driver is always
`repro.serving.runtime.ClusterRuntime`, over simulated engines (virtual
clock) or the real jitted-JAX engines of repro.serving.real_engine
(wall clock) — the scheduler cannot tell the difference.  It calls

    on_arrival(req, now)      when a request enters the system
    poll(now)                 -> list[DispatchCommand] to execute
    on_end_forward(ev)        when an engine finishes a forward pass
    next_event_time(now)      -> when poll() should next be called

SBS dual trigger (§ Fig 5): dispatch happens when BOTH
  (a) the adaptive interval I_opt has elapsed since the last dispatch, and
  (b) the round-robin target instance is ready (quiescent, signaled, or
      watchdog-reset — the multi-tier sync protocol).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.core.decode_alloc import (
    schedule_decode_batch, schedule_decode_global, schedule_decode_immediate,
)
from repro.core.flow_control import FlowAction, FlowController
from repro.core.interval import AdaptiveIntervalController
from repro.core.prefill_alloc import chunk_utilization, pbaa
from repro.core.prefix_cache import PrefixCacheIndex
from repro.core.state import GlobalState
from repro.core.sync import SyncProtocol
from repro.core.types import (
    DispatchCommand, EndForward, Request, RequestPhase,
)


class PrefillScheduler:
    """Interface."""

    def on_arrival(self, req: Request, now: float) -> None:
        raise NotImplementedError

    def poll(self, now: float) -> List[DispatchCommand]:
        raise NotImplementedError

    def on_end_forward(self, ev: EndForward) -> None:
        raise NotImplementedError

    def next_event_time(self, now: float) -> Optional[float]:
        raise NotImplementedError


class StaggeredBatchScheduler(PrefillScheduler):
    def __init__(self, state: GlobalState, n_limit: int = 8,
                 cache_aware: bool = False,
                 prefix_cache: Optional[PrefixCacheIndex] = None,
                 watchdog_multiplier: float = 5.0,
                 bucket_size: int = 0, bucket_max_wait: int = 4):
        self.state = state
        self.sync = SyncProtocol(state.num_prefill_instances,
                                 watchdog_multiplier)
        self.flow = FlowController(n_limit)
        self.n_limit = n_limit
        self.cache = prefix_cache if cache_aware else None
        self.buffer: List[Request] = []     # scheduler-side queue (new)
        self.pending: List[Request] = []    # PBAA leftovers (legacy)
        self.rejected: List[Request] = []
        self._target = 0                    # round-robin instance cursor
        self._last_dispatch = -float("inf")
        self._starved = False               # no capacity: wait for feedback
        self.cycles = 0
        self.util_history: List[float] = []
        # length-bucketed batch formation (BucketServe-style): queued
        # prompts are grouped by padded-length class inside the SBS
        # buffering window and ONE class dispatches per cycle, so
        # co-batched prompts pad to near-equal lengths.  bucket_size=0
        # disables (seed behavior: the whole buffer dispatches).
        self.bucket_size = max(int(bucket_size), 0)
        self.bucket_max_wait = max(int(bucket_max_wait), 1)
        self._bucket_wait: Dict[int, int] = {}   # class -> starved cycles
        self.padding_tokens_wasted = 0      # pad-to-batch-max token waste
        self.bucket_dispatches = 0          # dispatches that were bucketed

    # ------------------------------------------------------------------
    def reset_clock(self) -> None:
        """A new driver run restarts its clock at 0: clear the time-gated
        dispatch state so stamps from a previous run's timeline cannot
        stall the staggered interval (called by ClusterRuntime.run)."""
        self._last_dispatch = -float("inf")

    def on_arrival(self, req: Request, now: float) -> None:
        req.phase = RequestPhase.QUEUED
        self.buffer.append(req)
        self._starved = False

    def on_end_forward(self, ev: EndForward) -> None:
        self.state.on_end_forward(ev)
        self.sync.on_end_forward(ev.instance_id, ev.timestamp,
                                 remaining=ev.remaining_tokens,
                                 t_est=self.state.interval.t_fwd)
        self._starved = False

    # ------------------------------------------------------------------
    def _interval_elapsed(self, now: float) -> bool:
        return now - self._last_dispatch >= self.state.interval.interval - 1e-12

    def poll(self, now: float) -> List[DispatchCommand]:
        cmds: List[DispatchCommand] = []
        # allow draining multiple ready instances in one poll (catch-up after
        # a long gap), but each dispatch advances the staggered clock.
        while ((self.buffer or self.pending) and not self._starved
               and self._interval_elapsed(now)):
            target = self._next_ready_instance(now)
            if target is None:
                self._starved = True     # all busy: wait for EndForward
                break
            cmd = self._dispatch_to(target, now)
            if cmd is None:
                self._starved = True     # no capacity anywhere: wait
                break
            cmds.append(cmd)
            self._last_dispatch = now
        return cmds

    def _next_ready_instance(self, now: float) -> Optional[int]:
        n = self.state.num_prefill_instances
        # chunked-prefill tails are pinned to the DP holding their KV —
        # prefer dispatching to instances with pinned pending work so long
        # requests don't wait a full round-robin cycle between chunks
        dp2inst = {d.dp_id: d.instance_id for d in self.state.prefill_dps}
        pinned = {dp2inst[r.assigned_dp] for r in self.pending
                  if r.assigned_dp is not None and r.assigned_dp in dp2inst}
        candidates = [i for i in range(n) if i in pinned] + \
            [(self._target + k) % n for k in range(n)]
        for inst in candidates:
            if self.sync.is_ready(inst, now):
                self._target = (inst + 1) % n
                return inst
        return None

    # -- length-bucketed batch formation --------------------------------
    def _length_class(self, req: Request) -> int:
        """Padded-length class: prompts in one class pad to at most one
        `bucket_size` of waste when co-batched."""
        return max((req.input_len + self.bucket_size - 1)
                   // self.bucket_size, 1)

    def _select_bucket(self) -> List[Request]:
        """Pick ONE length class from the buffer; hold the rest back.

        Starved-first: a class that sat unselected for more than
        `bucket_max_wait` dispatch cycles wins outright (oldest starvation
        first), otherwise the class with the most queued prompt tokens
        dispatches — the one whose padding savings matter most."""
        classes: Dict[int, List[Request]] = {}
        for r in self.buffer:
            classes.setdefault(self._length_class(r), []).append(r)
        # drop wait state of emptied classes
        self._bucket_wait = {c: w for c, w in self._bucket_wait.items()
                             if c in classes}
        starved = [c for c in classes
                   if self._bucket_wait.get(c, 0) >= self.bucket_max_wait]
        if starved:
            chosen = max(starved, key=lambda c: self._bucket_wait.get(c, 0))
        else:
            chosen = max(classes,
                         key=lambda c: sum(r.input_len for r in classes[c]))
        for c in classes:
            if c == chosen:
                self._bucket_wait[c] = 0
            else:
                self._bucket_wait[c] = self._bucket_wait.get(c, 0) + 1
        held = [r for c, lst in classes.items() if c != chosen for r in lst]
        self.buffer = held
        return classes[chosen]

    def _note_padding(self, reqs: List[Request]) -> None:
        """Pad-to-batch-max waste of the NEW prompts entering this
        dispatch (the BucketServe metric; FLOPs-priced by the cost
        model's `padding_flops_wasted`)."""
        lens = [r.input_len for r in reqs]
        if len(lens) > 1:
            top = max(lens)
            self.padding_tokens_wasted += sum(top - ln for ln in lens)

    def _dispatch_to(self, inst: int, now: float) -> Optional[DispatchCommand]:
        dps = self.state.prefill_dps_of(inst)
        if self.bucket_size and self.buffer:
            new = self._select_bucket()     # holds other classes back
            self.bucket_dispatches += 1
        else:
            new = self.buffer
            self.buffer = []
        self._note_padding(new)
        assignments, q_next, over = pbaa(
            self.pending, new, dps, n_limit=self.n_limit,
            cache=self.cache)
        self.cycles += 1
        self.util_history.append(chunk_utilization(assignments, dps))
        # flow control on over-limit requests (per-request outcomes:
        # admit_request resets the wait-cycle clock if the verdict is
        # ADMIT, so a request that got through restarts from zero on the
        # next pressure episode)
        kept: List[Request] = []
        for r in over:
            act = self.flow.admit_request(r)
            if act == FlowAction.REJECT:
                r.phase = RequestPhase.REJECTED
                self.rejected.append(r)
            else:
                kept.append(r)
        self.pending = q_next + kept
        if not assignments:
            return None
        for dp_id, lst in assignments.items():
            for req, tok in lst:
                req.phase = RequestPhase.DISPATCHED
                req.assigned_instance = inst
                if req.dispatch_time is None:
                    req.dispatch_time = now
                if self.cache is not None and req.tokens is not None:
                    done = req.input_len - req.remaining_prefill
                    self.cache.insert(dp_id, req.tokens[:done])
        self.sync.on_dispatch(inst, now, self.state.interval.t_fwd)
        return DispatchCommand(instance_id=inst, assignments=assignments,
                               issue_time=now)

    def next_event_time(self, now: float) -> Optional[float]:
        cands = []
        if (self.buffer or self.pending) and not self._starved:
            cands.append(max(now,
                             self._last_dispatch + self.state.interval.interval))
        wd = self.sync.next_watchdog_deadline(now)
        if wd is not None:
            cands.append(wd)
        return min(cands) if cands else None


class ImmediatePrefillScheduler(PrefillScheduler):
    """Baseline (§3.2): requests are bound to an instance the moment they
    arrive and pile up in the engine's device-side queue (HOL blocking).
    Policies: round_robin | least_tokens (least outstanding work)."""

    def __init__(self, state: GlobalState, policy: str = "round_robin"):
        self.state = state
        self.policy = policy
        self._rr = 0
        self._out: List[DispatchCommand] = []
        # outstanding tokens per instance (scheduler's naive view)
        self._outstanding: Dict[int, int] = {
            i: 0 for i in range(state.num_prefill_instances)}
        self._dp_rr: Dict[int, int] = {
            i: 0 for i in range(state.num_prefill_instances)}
        self.rejected: List[Request] = []
        self.util_history: List[float] = []

    def on_arrival(self, req: Request, now: float) -> None:
        if self.policy == "round_robin":
            inst = self._rr % self.state.num_prefill_instances
            self._rr += 1
        elif self.policy == "least_tokens":
            inst = min(self._outstanding, key=self._outstanding.get)
        else:
            raise ValueError(self.policy)
        dps = self.state.prefill_dps_of(inst)
        j = self._dp_rr[inst] % len(dps)
        self._dp_rr[inst] += 1
        dp = dps[j]
        req.phase = RequestPhase.DISPATCHED
        req.assigned_instance = inst
        req.assigned_dp = dp.dp_id
        req.dispatch_time = now
        self._outstanding[inst] += req.input_len
        dp.on_dispatch(req.input_len)
        req.remaining_prefill = 0   # whole request pushed to the device
        self._out.append(DispatchCommand(
            instance_id=inst,
            assignments={dp.dp_id: [(req, req.input_len)]},
            issue_time=now))

    def poll(self, now: float) -> List[DispatchCommand]:
        out, self._out = self._out, []
        return out

    def on_end_forward(self, ev: EndForward) -> None:
        self.state.on_end_forward(ev)
        self._outstanding[ev.instance_id] = max(
            0, self._outstanding[ev.instance_id] - ev.processed_tokens)

    def next_event_time(self, now: float) -> Optional[float]:
        return now if self._out else None


# ---------------------------------------------------------------------------
# Decode-phase schedulers
# ---------------------------------------------------------------------------

class DecodeScheduler:
    """SBS decode side: buffer hand-offs inside the batching window, then
    batched placement. mode='immediate' degrades to the paper's baseline
    policies.

    Two batched allocators:
      alloc='lex'        — IQR-aware lexicographical placement
                           (Algorithm 3, batch-size first)
      alloc='load_aware' — Load-Aware Global Allocation: per-DP KV-token
                           load balanced within AND across instances

    Watchdog re-dispatch: the driver reports step completions through
    `on_step_end`; an instance holding dispatched work that has not
    completed a step within `watchdog_multiplier`×(EWMA step time) is
    reported by `stalled_instances` and quarantined. The driver drains it
    and re-places the stranded requests via `place_redispatch`, which
    excludes quarantined instances. Quarantine lifts on a healthy step or
    after one further budget of probation (a drained instance receives no
    work, so the next placement is what re-probes its health). The budget
    is not enforced until at least one real step time has been observed.

    `prefix_cache`, when given, makes placement CACHE-AWARE: the
    scheduler tracks which prompts each decode DP has hosted (a
    token-level `PrefixCacheIndex`, the same reuse model the sim plane
    and the real engines' page binders share) and prefers the DP holding
    the longest cached prefix of a new request — tie-broken by
    ⟨kv_occupancy, batch⟩ — for both the batched allocators and the
    immediate baseline."""

    def __init__(self, state: GlobalState, mode: str = "sbs",
                 policy: str = "round_robin", iqr_k: float = 1.5,
                 window: float = 0.05, alloc: str = "lex",
                 watchdog_multiplier: float = 0.0,
                 prefix_cache: Optional[PrefixCacheIndex] = None,
                 bucket_size: int = 0):
        if alloc not in ("lex", "load_aware"):
            raise ValueError(alloc)
        self.state = state
        self.mode = mode
        self.policy = policy
        self.iqr_k = iqr_k
        self.window = window
        self.alloc = alloc
        self.cache = prefix_cache
        # bucketed pricing: >0 groups each window batch by padded-length
        # class and runs the allocator once per class (largest first), so
        # the lex/load-aware allocators price near-equal-length groups
        # instead of a raw mixed-length batch
        self.bucket_size = max(int(bucket_size), 0)
        self.buffer: List[Request] = []
        self._rr = [0]
        self._last = -float("inf")
        # watchdog state
        self.wd_mult = watchdog_multiplier
        self.quarantined: set = set()
        self._quarantined_at: Dict[int, float] = {}
        self._step_est = 0.05           # EWMA of observed step durations
        self._observed = False          # armed only after a real step time
        self._waiting_since: Dict[int, float] = {}   # inst -> oldest unacked
        self._last_step: Dict[int, float] = {}

    def reset_clock(self) -> None:
        """New driver run, clock restarts at 0 — drop time stamps taken
        on the previous run's timeline (batching-window gate, watchdog
        bookkeeping).  Quarantine/EWMA state is timeline-free and kept."""
        self._last = -float("inf")
        self._last_step.clear()
        self._waiting_since.clear()
        self._quarantined_at.clear()
        self.quarantined.clear()    # idle between runs: re-probe on place

    def _affinity(self, req: Request, unit) -> int:
        """Cached-prefix tokens of `req` resident on `unit` (0 = none)."""
        if self.cache is None or req.tokens is None:
            return 0
        return self.cache.match(unit.dp_id, req.tokens,
                                limit=req.input_len)

    def _note_placed(self, out: Optional[Dict]) -> None:
        """Track placements in the scheduler-side reuse model: the DP the
        request joins will hold its prompt's KV (real plane: published
        into the DP's page binder at join)."""
        if self.cache is None or not out:
            return
        for dp_id, reqs in out.items():
            for r in reqs:
                if r.tokens is not None:
                    self.cache.insert(dp_id, r.tokens[:r.input_len])

    def _allocate(self, batch: List[Request]) -> Dict:
        if self.bucket_size and len(batch) > 1:
            classes: Dict[int, List[Request]] = {}
            for r in batch:
                c = max((r.input_len + self.bucket_size - 1)
                        // self.bucket_size, 1)
                classes.setdefault(c, []).append(r)
            if len(classes) > 1:
                # largest class first: it moves the per-DP KV budgets the
                # most, and later (smaller) classes then pack around it
                out: Dict[int, List[Request]] = {}
                for c in sorted(classes, reverse=True):
                    placed = self._allocate_one(classes[c])
                    for dp_id, reqs in (placed or {}).items():
                        out.setdefault(dp_id, []).extend(reqs)
                return out
        return self._allocate_one(batch)

    def _allocate_one(self, batch: List[Request]) -> Dict:
        aff = self._affinity if self.cache is not None else None
        if self.alloc == "load_aware":
            out = schedule_decode_global(
                batch, self.state.decode_dps, self.iqr_k,
                exclude_instances=frozenset(self.quarantined),
                affinity=aff)
        else:
            units = [u for u in self.state.decode_dps
                     if u.instance_id not in self.quarantined]
            out = schedule_decode_batch(
                batch, units or self.state.decode_dps, self.iqr_k)
        self._note_placed(out)
        return out

    def on_handoff(self, req: Request, now: float) -> Optional[Dict]:
        """Prefill finished (KV arrived over the P/D transfer — simulated
        delay or real cache handoff); route into a decode DP. Immediate
        mode places right away, SBS buffers until the window tick."""
        if self.mode == "immediate":
            out = schedule_decode_immediate(
                [req], self.state.decode_dps, self.policy, self._rr,
                affinity=self._affinity if self.cache is not None else None)
            self._note_placed(out)
            return out
        self.buffer.append(req)
        return None

    def poll(self, now: float) -> Optional[Dict]:
        if self.mode == "immediate" or not self.buffer:
            return None
        if now - self._last < self.window - 1e-12:
            return None
        batch, self.buffer = self.buffer, []
        self._last = now
        return self._allocate(batch)

    def next_event_time(self, now: float) -> Optional[float]:
        cands = []
        if self.mode != "immediate" and self.buffer:
            cands.append(max(now, self._last + self.window))
        if self.wd_mult > 0 and self._observed:
            budget = self.wd_mult * max(self._step_est, 1e-6)
            # quarantined instances cannot trip again until they step, so
            # their deadlines must not generate (repeated, past-due) ticks
            pend = [t for i, t in self._waiting_since.items()
                    if i not in self.quarantined]
            if pend:
                cands.append(min(pend) + budget)
            if self._quarantined_at:        # probation expiry wake-up
                cands.append(min(self._quarantined_at.values()) + budget)
        return min(cands) if cands else None

    # -- watchdog / re-dispatch path ------------------------------------

    def on_placed(self, placements: Dict[int, List[Request]], now: float
                  ) -> None:
        """Driver ack: requests physically admitted to instances."""
        if self.wd_mult <= 0:
            return
        dp2inst = {d.dp_id: d.instance_id for d in self.state.decode_dps}
        for dp_id in placements:
            self._waiting_since.setdefault(dp2inst[dp_id], now)

    def on_step_end(self, instance_id: int, now: float,
                    step_time: Optional[float] = None) -> None:
        """`step_time` is the measured duration of the step that just
        finished (preferred); without it the inter-completion gap is used,
        which over-estimates on idle instances."""
        if step_time is None:
            prev = self._last_step.get(instance_id)
            step_time = now - prev if (prev is not None and now > prev) \
                else None
        if step_time is not None:
            if not self._observed:
                self._step_est = step_time     # snap to the first real sample
                self._observed = True
            else:
                self._step_est = 0.8 * self._step_est + 0.2 * step_time
        self._last_step[instance_id] = now
        self._waiting_since.pop(instance_id, None)
        self.quarantined.discard(instance_id)
        self._quarantined_at.pop(instance_id, None)

    def stalled_instances(self, now: float) -> List[int]:
        if self.wd_mult <= 0 or not self._observed:
            return []          # no budget until a real step time is known
        budget = self.wd_mult * max(self._step_est, 1e-6)
        # probation: a drained instance gets no work (it is excluded from
        # allocation), so it can never step itself healthy — re-admit it
        # after one further budget and let the next placement re-probe it
        for inst, since in list(self._quarantined_at.items()):
            if now - since >= budget - 1e-9:
                self.quarantined.discard(inst)
                self._quarantined_at.pop(inst, None)
        out = []
        for inst, since in list(self._waiting_since.items()):
            if now - since >= budget - 1e-9 and inst not in self.quarantined:
                self.quarantined.add(inst)
                self._quarantined_at[inst] = now
                self._waiting_since.pop(inst, None)
                out.append(inst)
        return out

    def place_redispatch(self, reqs: List[Request], now: float
                         ) -> Optional[Dict]:
        if not reqs:
            return None
        return self._allocate(list(reqs))
