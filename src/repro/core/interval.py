"""Algorithm 1 — Throughput-Adaptive Interval Control Loop.

I_opt = (T̄_fwd + L_net) / N_active

T̄_fwd is a moving average over a sliding window of EndForward-reported
execution times; topology changes (auto-scaling, health-check) trigger an
immediate recompute.
"""
from __future__ import annotations

import collections
from typing import Deque


class AdaptiveIntervalController:
    def __init__(self, window_size: int = 32, l_net: float = 0.002,
                 t_default: float = 0.25, n_active: int = 1):
        if window_size <= 0:
            raise ValueError("window_size must be positive")
        self.window_size = window_size
        self.l_net = l_net
        self.t_default = t_default
        self._window: Deque[float] = collections.deque(maxlen=window_size)
        self._t_fwd = t_default
        self._n_active = max(n_active, 0)
        self._i_opt = self._compute()

    # -- Algorithm 1, RecomputeInterval --------------------------------
    def _compute(self) -> float:
        if self._n_active <= 0:
            return float("inf")      # no capacity: hold dispatch
        return (self._t_fwd + self.l_net) / self._n_active

    # -- Algorithm 1, OnEndForward --------------------------------------
    def on_end_forward(self, t_measured: float) -> float:
        """Feed one measured forward time; returns the new I_opt."""
        if t_measured < 0:
            raise ValueError("negative execution time")
        self._window.append(t_measured)   # deque evicts the oldest itself
        self._t_fwd = sum(self._window) / len(self._window)
        self._i_opt = self._compute()
        return self._i_opt

    # -- Algorithm 1, OnTopologyChange -----------------------------------
    def on_topology_change(self, n_new: int) -> float:
        self._n_active = max(n_new, 0)
        self._i_opt = self._compute()     # immediate adaptation
        return self._i_opt

    @property
    def interval(self) -> float:
        return self._i_opt

    @property
    def t_fwd(self) -> float:
        return self._t_fwd

    @property
    def n_active(self) -> int:
        return self._n_active

    @property
    def watchdog_timeout(self) -> float:
        """Safety-path timeout T = 5·T̄ (paper §4.1.2)."""
        return 5.0 * self._t_fwd
