"""Global State & Feedback System (paper Figure 5, plane 2).

Maintains the Global State Matrix ⟨C_avail, B_i, K_i⟩ from EndForward
feedback and drives the adaptive interval (Algorithm 1).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.core.interval import AdaptiveIntervalController
from repro.core.types import DecodeDPState, DPState, EndForward


class GlobalState:
    def __init__(
        self,
        num_prefill_instances: int,
        prefill_dp_per_instance: int,
        num_decode_instances: int,
        decode_dp_per_instance: int,
        chunk_size: int,
        interval: Optional[AdaptiveIntervalController] = None,
        max_batch_per_dp: int = 10_000,
        kv_budget_tokens: int = 10 ** 12,
        block_size: int = 0,
    ):
        self.chunk_size = chunk_size
        self.prefill_dps: List[DPState] = []
        for i in range(num_prefill_instances):
            for j in range(prefill_dp_per_instance):
                self.prefill_dps.append(DPState(
                    dp_id=i * prefill_dp_per_instance + j,
                    instance_id=i, c_chunk=chunk_size))
        self.decode_dps: List[DecodeDPState] = []
        for i in range(num_decode_instances):
            for j in range(decode_dp_per_instance):
                self.decode_dps.append(DecodeDPState(
                    dp_id=i * decode_dp_per_instance + j,
                    instance_id=i,
                    max_batch=max_batch_per_dp,
                    kv_budget=kv_budget_tokens,
                    block_size=block_size))
        self.interval = interval or AdaptiveIntervalController(
            n_active=num_prefill_instances)
        self.num_prefill_instances = num_prefill_instances
        self.num_decode_instances = num_decode_instances

    def prefill_dps_of(self, inst: int) -> List[DPState]:
        return [d for d in self.prefill_dps if d.instance_id == inst]

    def decode_dps_of(self, inst: int) -> List[DecodeDPState]:
        return [d for d in self.decode_dps if d.instance_id == inst]

    def on_end_forward(self, ev: EndForward) -> None:
        """Feedback-plane update: capacity release + interval adaptation."""
        for d in self.prefill_dps:
            if d.instance_id == ev.instance_id and d.dp_id == ev.dp_id:
                d.on_end_forward(ev.processed_tokens, ev.remaining_tokens)
        self.interval.on_end_forward(ev.exec_time)

    def snapshot(self) -> Dict:
        return {
            "c_avail": [d.c_avail for d in self.prefill_dps],
            "decode_B": [d.batch for d in self.decode_dps],
            "decode_K": [d.kv_tokens for d in self.decode_dps],
            "i_opt": self.interval.interval,
            "t_fwd": self.interval.t_fwd,
        }
