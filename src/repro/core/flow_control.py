"""Overload protection (Algorithm 2, phase 3).

When a request fails allocation for N_limit consecutive cycles the system is
saturated; the flow controller throttles (re-queue with backoff) or rejects,
preventing system-wide congestion collapse.

Two call sites consume the policy:

  * `StaggeredBatchScheduler._dispatch_to` — PBAA's phase-3 leftovers
    (requests unassigned for > N_limit prefill cycles).
  * `ClusterRuntime` admission control — arrivals while the decode pool
    is saturated are throttled (their arrival event re-enters the heap
    after `backoff(...)` seconds) and eventually rejected.

Stats are PER-REQUEST OUTCOMES, not per-cycle decisions: a request polled
for 8 cycles and then admitted counts once under `admitted`, never 8
times.  A request's outcome is its LATEST decision — throttled requests
that are later admitted migrate buckets.  Priority classes tighten the
reject horizon for less urgent work: priority 0 keeps the full
`n_limit × reject_after` budget, each step down the ladder sheds one
`reject_after` multiple (floor 1), so under sustained overload batch
traffic is rejected first and interactive traffic last.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional


class FlowAction(str, enum.Enum):
    ADMIT = "admit"
    THROTTLE = "throttle"
    REJECT = "reject"


@dataclasses.dataclass
class FlowControlStats:
    throttled: int = 0
    rejected: int = 0
    admitted: int = 0


class FlowController:
    """Two-level policy: first breach throttles (backoff + re-queue at the
    head, preserving FCFS), sustained breach rejects."""

    def __init__(self, n_limit: int = 8, reject_after: int = 3,
                 backoff_base: float = 0.05):
        self.n_limit = n_limit
        self.reject_after = reject_after
        self.backoff_base = backoff_base
        self._outcomes: Dict[int, FlowAction] = {}   # rid -> latest decision
        self._anon = FlowControlStats()              # rid-less legacy calls

    def _reject_cycles(self, priority: int) -> int:
        """Cycles before a priority class is rejected outright."""
        return self.n_limit * max(self.reject_after - max(priority, 0), 1)

    def decide(self, wait_cycles: int, rid: Optional[int] = None,
               priority: int = 0) -> FlowAction:
        """Policy decision for a request that has waited `wait_cycles`
        allocation cycles.  With `rid`, the decision is recorded as the
        request's (latest) outcome; without it the call is counted as an
        anonymous terminal event (legacy behaviour for callers that only
        probe the policy once per request)."""
        if wait_cycles <= self.n_limit:
            act = FlowAction.ADMIT
        elif wait_cycles <= self._reject_cycles(priority):
            act = FlowAction.THROTTLE
        else:
            act = FlowAction.REJECT
        if rid is not None:
            self._outcomes[rid] = act
        else:
            if act == FlowAction.ADMIT:
                self._anon.admitted += 1
            elif act == FlowAction.THROTTLE:
                self._anon.throttled += 1
            else:
                self._anon.rejected += 1
        return act

    def admit_request(self, req) -> FlowAction:
        """`decide` for a `Request`: the wait-cycle state RESETS on admit
        (the request got through — a later pressure episode starts its
        throttle clock from zero, instead of inheriting a stale counter
        that would reject it on first contact)."""
        act = self.decide(req.wait_cycles, rid=req.rid,
                          priority=req.priority)
        if act == FlowAction.ADMIT:
            req.wait_cycles = 0
        return act

    def gate(self, req, saturated: bool) -> FlowAction:
        """Runtime admission gate (arrival-time overload control).
        While `saturated`, the request is throttled IMMEDIATELY — no
        n_limit grace, since admitting into a saturated pool only
        deepens the queue — escalating to REJECT past its class's
        horizon.  Once pressure drops it admits and its wait state
        resets, so a later episode starts the clock from zero."""
        if not saturated:
            # unconditional: routing through `decide` would keep
            # throttling any request whose saturated-phase wait already
            # passed n_limit (wait_cycles never advances on this path —
            # a livelock, not a policy)
            self._outcomes[req.rid] = FlowAction.ADMIT
            req.wait_cycles = 0
            return FlowAction.ADMIT
        req.wait_cycles += 1
        act = (FlowAction.REJECT
               if req.wait_cycles > self._reject_cycles(req.priority)
               else FlowAction.THROTTLE)
        self._outcomes[req.rid] = act
        return act

    def backoff(self, wait_cycles: int) -> float:
        """Throttle re-queue delay: doubles per cycle past n_limit,
        capped at 32× the base."""
        excess = max(wait_cycles - self.n_limit, 0)
        return self.backoff_base * min(2 ** excess, 32)

    @property
    def stats(self) -> FlowControlStats:
        """Per-request terminal outcomes (latest decision per rid), plus
        any rid-less legacy decisions."""
        s = FlowControlStats(admitted=self._anon.admitted,
                             throttled=self._anon.throttled,
                             rejected=self._anon.rejected)
        for act in self._outcomes.values():
            if act == FlowAction.ADMIT:
                s.admitted += 1
            elif act == FlowAction.THROTTLE:
                s.throttled += 1
            else:
                s.rejected += 1
        return s
