"""Overload protection (Algorithm 2, phase 3).

When a request fails allocation for N_limit consecutive cycles the system is
saturated; the flow controller throttles (re-queue with backoff) or rejects,
preventing system-wide congestion collapse.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional


class FlowAction(str, enum.Enum):
    ADMIT = "admit"
    THROTTLE = "throttle"
    REJECT = "reject"


@dataclasses.dataclass
class FlowControlStats:
    throttled: int = 0
    rejected: int = 0
    admitted: int = 0


class FlowController:
    """Two-level policy: first breach throttles (backoff + re-queue at the
    head, preserving FCFS), sustained breach rejects."""

    def __init__(self, n_limit: int = 8, reject_after: int = 3):
        self.n_limit = n_limit
        self.reject_after = reject_after
        self.stats = FlowControlStats()

    def decide(self, wait_cycles: int) -> FlowAction:
        if wait_cycles <= self.n_limit:
            self.stats.admitted += 1
            return FlowAction.ADMIT
        if wait_cycles <= self.n_limit * self.reject_after:
            self.stats.throttled += 1
            return FlowAction.THROTTLE
        self.stats.rejected += 1
        return FlowAction.REJECT
