"""§4.1.2 — Multi-tier (Robust) State Synchronization Protocol.

Triple-check readiness:
  1. Quiescence polling  (init path)  — zero task depth => ready.
  2. EndForward signal   (fast path)  — event-driven readiness.
  3. Liveness watchdog   (safety path)— T_timeout = 5·T̄; expiry forces a
     state reset so lost signals cannot deadlock the cluster; repeated
     expiries degrade the instance into fixed-interval mode.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Optional


class Readiness(str, enum.Enum):
    READY_QUIESCENT = "quiescent"
    READY_SIGNAL = "signal"
    READY_WATCHDOG = "watchdog"     # forced reset (degraded)
    BUSY = "busy"


@dataclasses.dataclass
class _InstanceSync:
    busy: bool = False
    task_depth: int = 0
    dispatch_time: Optional[float] = None
    watchdog_deadline: Optional[float] = None
    watchdog_trips: int = 0
    degraded: bool = False


class SyncProtocol:
    def __init__(self, num_instances: int, watchdog_multiplier: float = 5.0,
                 degrade_after_trips: int = 3):
        self._st: Dict[int, _InstanceSync] = {
            i: _InstanceSync() for i in range(num_instances)}
        self.mult = watchdog_multiplier
        self.degrade_after = degrade_after_trips

    # -- scheduler-side events -------------------------------------------
    def on_dispatch(self, inst: int, now: float, t_fwd_est: float) -> None:
        s = self._st[inst]
        s.busy = True
        s.task_depth += 1
        s.dispatch_time = now
        s.watchdog_deadline = now + self.mult * max(t_fwd_est, 1e-6)

    # -- engine-side events ----------------------------------------------
    def on_end_forward(self, inst: int, now: float, remaining: int = 0,
                       t_est: float = 0.1) -> None:
        """remaining > 0 means the engine still has device-side backlog and
        will auto-run another pass — it is NOT quiescent (paper §4.1.2:
        quiescence polling watches the instance queue's task depth)."""
        s = self._st[inst]
        s.task_depth = max(0, s.task_depth - 1)
        if remaining > 0:
            s.task_depth = max(s.task_depth, 1)
            s.busy = True
            s.watchdog_deadline = now + self.mult * max(t_est, 1e-6)
        elif s.task_depth == 0:
            s.busy = False
            s.watchdog_deadline = None
        s.watchdog_trips = 0            # healthy signal clears degradation
        s.degraded = False

    # -- readiness check (triple path) -------------------------------------
    def readiness(self, inst: int, now: float) -> Readiness:
        s = self._st[inst]
        if s.task_depth == 0:
            return Readiness.READY_QUIESCENT          # path 1
        if not s.busy:
            return Readiness.READY_SIGNAL             # path 2
        if s.watchdog_deadline is not None and now >= s.watchdog_deadline:
            # path 3: force reset — prevents distributed deadlock
            s.task_depth = 0
            s.busy = False
            s.watchdog_deadline = None
            s.watchdog_trips += 1
            if s.watchdog_trips >= self.degrade_after:
                s.degraded = True       # fixed-interval fallback mode
            return Readiness.READY_WATCHDOG
        return Readiness.BUSY

    def is_ready(self, inst: int, now: float) -> bool:
        return self.readiness(inst, now) != Readiness.BUSY

    def is_degraded(self, inst: int) -> bool:
        return self._st[inst].degraded

    def task_depth(self, inst: int) -> int:
        return self._st[inst].task_depth

    def next_watchdog_deadline(self, now: float) -> Optional[float]:
        ds = [s.watchdog_deadline for s in self._st.values()
              if s.watchdog_deadline is not None and s.watchdog_deadline > now]
        return min(ds) if ds else None
