"""Core datatypes for the SBS scheduler (paper §4, Figure 5).

The scheduler's world is: requests, DP units (the atomic scheduling unit in
DP+EP systems, §3.1), instances (groups of DP units joined by a
synchronization barrier), and EndForward feedback signals.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Tuple


def blocks_for_tokens(tokens: int, block_size: int) -> int:
    """Physical KV blocks needed to hold `tokens` entries — THE ceiling
    rule shared by scheduler-side reservation (`DecodeDPState`), the
    engine-side allocator (`serving.kv_pool.BlockPool`) and benchmarks,
    so the two admission layers can never drift apart."""
    if tokens <= 0:
        return 0
    return -(-tokens // block_size)


class RequestPhase(str, enum.Enum):
    QUEUED = "queued"            # scheduler-side queue (SBS buffer)
    DISPATCHED = "dispatched"    # in flight to / inside an engine
    PREFILLING = "prefilling"
    DECODING = "decoding"
    PREEMPTED = "preempted"      # swapped out; KV parked, awaiting re-admit
    FINISHED = "finished"
    REJECTED = "rejected"        # flow control


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """A service class: dispatch priority (0 = most urgent) plus the
    end-to-end latency target its requests are judged against (goodput =
    the throughput of requests that finish within their class SLO)."""
    name: str
    priority: int
    slo_e2e: float


#: default class ladder — workload generation samples from these, victim
#: selection / PBAA / decode allocation order by `priority`, and the
#: goodput report buckets by `name`.  Override per deployment by building
#: Requests with explicit `priority` / `slo_e2e` fields.
SLO_CLASSES: Dict[str, SLOClass] = {
    "interactive": SLOClass("interactive", 0, 5.0),
    "standard": SLOClass("standard", 1, 20.0),
    "batch": SLOClass("batch", 2, 120.0),
}

DEFAULT_SLO_CLASS = "standard"


@dataclasses.dataclass
class Request:
    rid: int
    arrival_time: float
    input_len: int
    output_len: int = 1
    tokens: Optional[Tuple[int, ...]] = None    # actual ids (prefix caching)
    phase: RequestPhase = RequestPhase.QUEUED
    # SLO / priority class (overload control).  priority 0 is the most
    # urgent; slo_e2e None falls back to the report-level default SLO.
    priority: int = 1
    slo_e2e: Optional[float] = None
    slo_class: str = DEFAULT_SLO_CLASS
    # scheduling bookkeeping
    wait_cycles: int = 0                        # PBAA starvation counter
    remaining_prefill: int = 0                  # tokens not yet prefetched
    inflight: int = 0                           # granted, not yet processed
    generated: int = 0
    assigned_dp: Optional[int] = None
    assigned_instance: Optional[int] = None
    migrations: int = 0                         # decode watchdog re-dispatches
    preemptions: int = 0                        # page-level swap-outs
    # timestamps
    dispatch_time: Optional[float] = None
    prefill_start: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None

    def __post_init__(self):
        if self.remaining_prefill == 0:
            self.remaining_prefill = self.input_len

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    def deadline(self, default_slo: Optional[float] = None
                 ) -> Optional[float]:
        """Absolute wall/virtual time by which the request must finish to
        count toward goodput; None when no SLO applies."""
        slo = self.slo_e2e if self.slo_e2e is not None else default_slo
        if slo is None:
            return None
        return self.arrival_time + slo

    def slo_attained(self, default_slo: Optional[float] = None) -> bool:
        """Finished within its SLO?  Unfinished/rejected never attain."""
        if self.finish_time is None:
            return False
        d = self.deadline(default_slo)
        return d is None or self.finish_time <= d

    @property
    def queueing_delay(self) -> Optional[float]:
        if self.dispatch_time is None:
            return None
        return self.dispatch_time - self.arrival_time

    @property
    def device_queue_delay(self) -> Optional[float]:
        """HOL blocking inside the engine (paper §3.2: the unmanageable part)."""
        if self.prefill_start is None or self.dispatch_time is None:
            return None
        return self.prefill_start - self.dispatch_time


@dataclasses.dataclass
class DPState:
    """Real-time prefill capacity model (paper §4.2.1):
    C_avail = C_chunk − U_flight − R_queued."""
    dp_id: int
    instance_id: int
    c_chunk: int
    u_flight: int = 0       # dispatched but unacknowledged tokens
    r_queued: int = 0       # backlog buffered on the device

    @property
    def c_avail(self) -> int:
        return self.c_chunk - self.u_flight - self.r_queued

    def on_dispatch(self, tokens: int) -> None:
        self.u_flight += tokens

    def on_end_forward(self, processed: int, remaining: int) -> None:
        """EndForward payload: tokens consumed + backlog remaining (§ Fig 5)."""
        self.u_flight = max(0, self.u_flight - processed - remaining)
        self.r_queued = remaining


@dataclasses.dataclass
class DecodeDPState:
    """Decode DP unit state vector V_i = ⟨B_i, K_i⟩ (paper §4.3.3).

    With `block_size` > 0 the unit additionally tracks PAGED occupancy:
    each admitted request reserves ceil(total_len / block_size) physical
    KV blocks for its lifetime, where total_len = input + output.  This
    is a CONSERVATIVE UPPER BOUND on the device-side allocation: the sim
    plane really holds input+output resident tokens at finish, while the
    real engine's `BlockPool` reserves for input + min(output, max_new)
    − 1 (the final sampled token never enters the cache, and the
    scheduler cannot see the engine's max_new cap).  Over-reservation
    only delays admission — the engine's pending-retry path absorbs the
    slack — and admit/release are symmetric, so nothing leaks.  Budget
    masking and the cost model then see `kv_occupancy` — block-granular,
    fragmentation included — while `kv_tokens` stays the exact
    resident-token load."""
    dp_id: int
    instance_id: int
    batch: int = 0          # B_i — number of running decode requests
    kv_tokens: int = 0      # K_i — total KV-cache tokens resident
    max_batch: int = 10_000
    kv_budget: int = 10 ** 12
    block_size: int = 0     # 0 = token-granular (padded-slot) accounting
    kv_blocks: int = 0      # physical blocks reserved (block_size > 0)

    def _blocks_for(self, tokens: int) -> int:
        return blocks_for_tokens(tokens, self.block_size)

    @property
    def kv_occupancy(self) -> int:
        """KV footprint for budgets/cost: reserved-block tokens when
        paged (internal fragmentation included), raw tokens otherwise."""
        if self.block_size:
            return self.kv_blocks * self.block_size
        return self.kv_tokens

    def admit(self, kv_len: int, reserve_len: Optional[int] = None) -> None:
        """`reserve_len` is the request's lifetime KV length (input +
        output) — what the paged plane reserves blocks for up front."""
        self.batch += 1
        self.kv_tokens += kv_len
        if self.block_size:
            self.kv_blocks += self._blocks_for(
                kv_len if reserve_len is None else reserve_len)

    def step(self, n: Optional[int] = None) -> None:
        """Each stepped request grows by 1 KV token.  `n` is the number of
        requests that actually participated in the step — on the real
        plane this can lag `batch` (admitted requests join the padded
        batch only between steps), so engines pass it explicitly.  Paged
        block reservations do not move here: they were taken at admit."""
        self.kv_tokens += self.batch if n is None else n

    def release(self, kv_len: int, reserve_len: Optional[int] = None) -> None:
        self.batch = max(0, self.batch - 1)
        self.kv_tokens = max(0, self.kv_tokens - kv_len)
        if self.block_size:
            self.kv_blocks = max(0, self.kv_blocks - self._blocks_for(
                kv_len if reserve_len is None else reserve_len))


@dataclasses.dataclass
class EndForward:
    """Asynchronous completion signal (paper §4.1.2 fast path)."""
    instance_id: int
    dp_id: int
    exec_time: float               # measured forward-pass duration
    processed_tokens: int = 0
    remaining_tokens: int = 0      # backlog depth (payload statistics)
    timestamp: float = 0.0


@dataclasses.dataclass
class DispatchCommand:
    """Scheduler → engine: one batch for one instance's DP units."""
    instance_id: int
    # per-DP token budget map: dp_id -> list of (request, tokens_this_chunk)
    assignments: Dict[int, List[Tuple[Request, int]]]
    issue_time: float = 0.0

    @property
    def total_tokens(self) -> int:
        return sum(t for lst in self.assignments.values() for _, t in lst)
