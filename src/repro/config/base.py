"""Config system: model/parallel/serving/train configs + architecture registry.

Every assigned architecture registers a ``ModelConfig`` factory under its id;
``get_arch(name)`` returns the full config, ``get_arch(name, reduced=True)``
returns the ≤2-layer smoke variant of the same family.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Callable, Dict, List, Optional, Tuple


class AttentionKind(str, enum.Enum):
    GQA = "gqa"            # grouped-query (covers MHA when kv==heads)
    MLA = "mla"            # multi-head latent attention (DeepSeek)
    SWA = "swa"            # sliding-window GQA
    NONE = "none"          # attention-free layer (SSM)


class LayerKind(str, enum.Enum):
    DENSE = "dense"        # attention + dense MLP
    MOE = "moe"            # attention + MoE MLP
    SSM = "ssm"            # Mamba2 SSD block (+ dense or MoE MLP optional)
    SSM_MOE = "ssm_moe"    # Mamba2 block with MoE MLP (jamba)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 0
    d_expert: int = 0              # per-expert FFN hidden dim
    num_shared: int = 0            # shared (always-on) experts
    d_shared: int = 0              # shared-expert hidden dim
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    routed_scaling: float = 1.0    # deepseek-v3 routed_scaling_factor
    score_fn: str = "softmax"      # "softmax" | "sigmoid" (deepseek-v3)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1              # B/C projection groups (mamba2)
    chunk_size: int = 256
    # n_heads = d_model * expand // head_dim (derived)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 0           # 0 => no q compression
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 => d_model // num_heads
    attention: AttentionKind = AttentionKind.GQA
    # layer_pattern: maps layer index -> LayerKind. Encoded as a repeating
    # pattern tuple applied cyclically, plus an optional dense prefix
    # (deepseek-v3 uses 3 dense layers then MoE).
    layer_pattern: Tuple[LayerKind, ...] = (LayerKind.DENSE,)
    dense_prefix: int = 0
    moe: MoEConfig = MoEConfig()
    ssm: SSMConfig = SSMConfig()
    mla: MLAConfig = MLAConfig()
    sliding_window: int = 0        # SWA window (tokens); 0 => full attention
    rope_theta: float = 10000.0
    max_seq_len: int = 32768
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # encoder-decoder (whisper): encoder layers use self-attn only; decoder
    # layers add cross-attention to encoder output.
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq_len: int = 0       # fixed frontend length (whisper frames)
    # VLM: prefix of patch embeddings injected before text tokens.
    num_patch_tokens: int = 0
    # Multi-token prediction (deepseek-v3): extra MTP depth.
    mtp_depth: int = 0
    # activation dtype for large-scale lowering
    dtype: str = "bfloat16"
    source: str = ""               # citation

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    def layer_kind(self, i: int) -> LayerKind:
        if i < self.dense_prefix:
            return LayerKind.DENSE
        j = i - self.dense_prefix
        return self.layer_pattern[j % len(self.layer_pattern)]

    def layer_kinds(self) -> List[LayerKind]:
        return [self.layer_kind(i) for i in range(self.num_layers)]

    @property
    def is_subquadratic(self) -> bool:
        """True if decode state is bounded (SSM/hybrid/SWA) => long_500k ok."""
        kinds = set(self.layer_kinds())
        has_full_attn = any(
            k in (LayerKind.DENSE, LayerKind.MOE) for k in kinds
        ) and self.attention in (AttentionKind.GQA, AttentionKind.MLA)
        if self.attention == AttentionKind.SWA and self.sliding_window > 0:
            return True
        if not has_full_attn:
            return True  # pure SSM
        # hybrid: attention layers exist but are a small fraction; decode KV
        # grows linearly yet stays feasible — the task assigns jamba to run.
        n_attn = sum(
            1 for i in range(self.num_layers)
            if self.layer_kind(i) in (LayerKind.DENSE, LayerKind.MOE)
            and self.attention != AttentionKind.NONE
        )
        return self.family == "hybrid" and n_attn * 4 <= self.num_layers

    # ---- parameter counting (for roofline MODEL_FLOPS = 6·N·D) ----
    def param_counts(self) -> Dict[str, float]:
        d, v = self.d_model, self.vocab_size
        hd = self.resolved_head_dim
        nh, nkv = self.num_heads, self.num_kv_heads
        embed = v * d * (1 if self.tie_embeddings else 2)
        total = embed
        active = embed
        enc_layers = self.num_encoder_layers if self.is_encoder_decoder else 0
        for i in range(self.num_layers + enc_layers):
            is_enc = i >= self.num_layers
            kind = LayerKind.DENSE if is_enc else self.layer_kind(i)
            # attention params
            if kind in (LayerKind.DENSE, LayerKind.MOE):
                if self.attention == AttentionKind.MLA and not is_enc:
                    m = self.mla
                    qin = m.q_lora_rank or d
                    attn = 0.0
                    if m.q_lora_rank:
                        attn += d * m.q_lora_rank
                    attn += qin * nh * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                    attn += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    attn += m.kv_lora_rank * nh * (m.qk_nope_head_dim + m.v_head_dim)
                    attn += nh * m.v_head_dim * d
                else:
                    attn = d * nh * hd + 2 * d * nkv * hd + nh * hd * d
                if is_enc or (self.is_encoder_decoder and not is_enc):
                    pass
                if self.is_encoder_decoder and not is_enc:
                    attn *= 2  # + cross attention
            elif kind in (LayerKind.SSM, LayerKind.SSM_MOE):
                di = d * self.ssm.expand
                nheads = di // self.ssm.head_dim
                attn = (
                    d * (2 * di + 2 * self.ssm.n_groups * self.ssm.d_state + nheads)
                    + (di + 2 * self.ssm.n_groups * self.ssm.d_state) * self.ssm.d_conv
                    + di * d
                )
            else:
                attn = 0.0
            # mlp params
            if kind in (LayerKind.MOE, LayerKind.SSM_MOE) and self.moe.num_experts:
                mc = self.moe
                per_exp = 3 * d * mc.d_expert
                mlp_total = mc.num_experts * per_exp + d * mc.num_experts
                mlp_total += mc.num_shared * 3 * d * mc.d_shared
                mlp_active = mc.top_k * per_exp + d * mc.num_experts
                mlp_active += mc.num_shared * 3 * d * mc.d_shared
            elif kind in (LayerKind.DENSE,):
                mlp_total = mlp_active = 3 * d * self.d_ff
            elif kind == LayerKind.SSM and self.d_ff:
                mlp_total = mlp_active = 3 * d * self.d_ff
            else:
                mlp_total = mlp_active = 0.0
            total += attn + mlp_total
            active += attn + mlp_active
        return {"total": float(total), "active": float(active)}


# ---------------------------------------------------------------------------
# Parallel / serving / train configs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """How the model maps onto the device mesh.

    Axes: optional "pod" (slowest), "data" (batch / sequence / FSDP),
    "model" (TP heads / ff, EP experts).
    """
    data_axes: Tuple[str, ...] = ("data",)     # batch sharding axes
    model_axis: str = "model"
    expert_axes: Tuple[str, ...] = ("model",)  # expert-dim sharding (EP)
    fsdp_params: bool = False                  # shard params over data too
    fsdp_axes: Tuple[str, ...] = ("data",)
    shard_seq_for_decode: bool = True          # long-context: KV seq on data
    remat: str = "block"                       # none | block | full
    zero1: bool = True                         # shard optimizer state on data


# default paged batch rows per padded-equivalent slot: the ONE source of
# the 2× rule — ServingConfig.resolved_decode_slots (scheduler admission)
# and EngineSpec.paged_slots (engine batch rows) both derive from it, so
# the scheduler can never hand out more slots than the engine allocates
PAGED_SLOTS_FACTOR = 2


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """SBS scheduler + cluster parameters (paper §4 / §5)."""
    # cluster topology (paper: 3P1D, prefill TP4/DP8, decode DP32)
    num_prefill_instances: int = 3
    num_decode_instances: int = 1
    prefill_dp_per_instance: int = 8
    decode_dp_per_instance: int = 32
    chunk_size: int = 3072                  # C_chunk (paper: 3K/5K/16K)
    # Algorithm 1
    window_size: int = 32                   # W_size sliding window
    l_net: float = 0.002                    # network latency (s)
    t_default: float = 0.25                 # T_default initial forward time
    # Algorithm 2
    n_limit: int = 8                        # max waiting cycles before throttle
    cache_aware: bool = False
    # Algorithm 3
    iqr_k: float = 1.5
    # sync protocol
    watchdog_multiplier: float = 5.0
    # decode capacity
    max_batch_per_dp: int = 64
    kv_budget_tokens: int = 200_000         # per-DP KV token budget
    # paged KV cache (0 = padded max_len slots).  With paging on, decode
    # admission is gated by free KV *blocks* (block_size tokens each)
    # instead of free slots, so a DP holds more concurrent requests at
    # the same memory budget; max_batch_per_dp keeps its meaning as the
    # padded-equivalent memory budget (slots × max_len tokens).
    block_size: int = 0
    decode_slots_per_dp: int = 0            # 0 => auto (see resolved_decode_slots)
    # SLO-aware overload control.  `preemption` arms page-level decode
    # preemption: when a waiter cannot be admitted (real plane: free
    # blocks short; sim plane: KV budget exceeded), lower-priority
    # residents are swapped out (KV parked with generation state) and
    # re-admitted through the normal join path when pressure drops.
    # `flow_control` arms the runtime's arrival gate: while the decode
    # pool is saturated, arrivals are throttled (re-queued with
    # exponential backoff) and eventually rejected, least-urgent
    # priority class first.  `slo_default` is the E2E deadline used for
    # goodput when a request carries no per-class slo_e2e.
    preemption: bool = False
    flow_control: bool = False
    flow_backoff: float = 0.05
    slo_default: float = 20.0
    # Unified mixed-batch plane (Sarathi-style piggybacking).  With
    # `mixed_batch` on, the deployment runs ONE pool of unified engines:
    # prompts are admitted directly to the decode plane and their
    # chunked-prefill work rides the leftover per-step token budget
    # (`mixed_chunk − decode_rows`) of the SAME forward pass the decode
    # rows run in, so decode never stalls behind a prefill pass.
    # `prefill_starve_limit` bounds lockout: after that many consecutive
    # steps where pending prefill got zero budget, the next step grants
    # a chunk regardless of decode load.  `mixed_piggyback=False` is the
    # ablation leg (disjoint steps on the same engine: a step runs
    # EITHER the pending prefill chunk OR the decode rows) used by the
    # real-plane A/B.
    mixed_batch: bool = False
    mixed_chunk: int = 0                    # per-DP step token budget (0 => chunk_size)
    prefill_starve_limit: int = 4
    mixed_piggyback: bool = True
    # Length-bucketed batch formation (BucketServe) inside the SBS
    # buffering window: queued prompts are grouped by padded-length
    # class (`ceil(input_len / bucket_size)`) and a dispatch draws from
    # whole buckets — starved buckets (held back `bucket_max_wait`
    # dispatch cycles) first, then densest — so co-batched prompts pad
    # to a common boundary instead of the batch max.  0 disables.
    bucket_size: int = 0
    bucket_max_wait: int = 4

    def __post_init__(self):
        if self.decode_slots_per_dp and not self.block_size:
            # paged-only knob: on the padded plane slots ARE the memory
            # (max_batch_per_dp × max_len), so a divergent slot count
            # would let the scheduler admit more than engines allocate
            raise ValueError(
                "decode_slots_per_dp requires block_size > 0 (padded "
                "slots are fixed by max_batch_per_dp)")
        if self.mixed_chunk and not self.mixed_batch:
            raise ValueError(
                "mixed_chunk is only meaningful with mixed_batch=True")

    @property
    def resolved_mixed_chunk(self) -> int:
        """Per-DP token budget of one unified step: decode rows cost one
        token each, the remainder is the prefill piggyback allowance."""
        return self.mixed_chunk or self.chunk_size

    @property
    def resolved_decode_slots(self) -> int:
        """Batch rows per decode DP.  Padded: one row per max_len slot
        (max_batch_per_dp).  Paged: default PAGED_SLOTS_FACTOR× — rows
        are cheap (the KV memory lives in the shared block pool), the
        real gate is the free-block count."""
        if self.decode_slots_per_dp:
            return self.decode_slots_per_dp
        if self.block_size:
            return self.max_batch_per_dp * PAGED_SLOTS_FACTOR
        return self.max_batch_per_dp


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    global_batch: int = 256
    seq_len: int = 4096
    lr: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    schedule: str = "wsd"                  # wsd | cosine | constant
    warmup_steps: int = 100
    stable_frac: float = 0.8               # WSD stable fraction
    total_steps: int = 1000
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                              # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Architecture registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[bool], ModelConfig]] = {}


def register_arch(name: str):
    def deco(fn: Callable[[bool], ModelConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_arch(name: str, reduced: bool = False) -> ModelConfig:
    import repro.configs  # noqa: F401  (populate registry)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name](reduced)


def list_archs() -> List[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)
