"""repro: Staggered Batch Scheduling (SBS) - JAX serving framework.

Implements Tian et al., "Staggered Batch Scheduling: Co-optimizing
Time-to-First-Token and Throughput for High-Efficiency LLM Inference"
(CS.DC 2025) as a production-shaped JAX serving/training framework.
"""

__version__ = "0.1.0"
