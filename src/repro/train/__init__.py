from repro.train.optimizer import adamw_init, adamw_update, clip_by_global_norm
from repro.train.schedule import make_schedule, wsd_schedule
from repro.train.trainer import Trainer, make_train_step

__all__ = [
    "adamw_init", "adamw_update", "clip_by_global_norm",
    "make_schedule", "wsd_schedule", "Trainer", "make_train_step",
]
