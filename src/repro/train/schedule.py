"""LR schedules. WSD (Warmup-Stable-Decay) is the MiniCPM schedule
[arXiv:2404.06395 §4]: linear warmup → constant plateau → exponential-ish
decay tail (we use the paper's 1-sqrt variant linearly-interpolable form)."""
from __future__ import annotations

import math
from typing import Callable

import jax.numpy as jnp


def wsd_schedule(peak_lr: float, warmup_steps: int, total_steps: int,
                 stable_frac: float = 0.8, final_frac: float = 0.1
                 ) -> Callable:
    decay_start = int(total_steps * stable_frac)
    decay_steps = max(total_steps - decay_start, 1)

    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(step / max(warmup_steps, 1), 1.0)
        frac = jnp.clip((step - decay_start) / decay_steps, 0.0, 1.0)
        decay = peak_lr * (1.0 - (1.0 - final_frac) * jnp.sqrt(frac))
        return jnp.where(step < decay_start, warm, decay)
    return fn


def cosine_schedule(peak_lr: float, warmup_steps: int, total_steps: int,
                    final_frac: float = 0.1) -> Callable:
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(step / max(warmup_steps, 1), 1.0)
        t = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1),
                     0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(math.pi * t))
        return jnp.where(step < warmup_steps, warm, peak_lr * cos)
    return fn


def make_schedule(name: str, peak_lr: float, warmup_steps: int,
                  total_steps: int, stable_frac: float = 0.8) -> Callable:
    if name == "wsd":
        return wsd_schedule(peak_lr, warmup_steps, total_steps, stable_frac)
    if name == "cosine":
        return cosine_schedule(peak_lr, warmup_steps, total_steps)
    if name == "constant":
        return lambda step: jnp.full((), peak_lr, jnp.float32)
    raise ValueError(name)
