"""AdamW + global-norm clipping in pure JAX (pytree-native).

Moments are stored in f32 regardless of param dtype; with
ParallelConfig.zero1 the launcher shards the moment pytrees over the data
axis (ZeRO-1).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gnorm


def adamw_update(params, grads, opt_state, lr,
                 beta1: float = 0.9, beta2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1,
                 grad_clip: float = 1.0):
    """One AdamW step. Returns (new_params, new_opt_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, grad_clip)
    step = opt_state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - beta1 ** t
    bc2 = 1.0 - beta2 ** t

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu = beta1 * mu + (1 - beta1) * g32
        nu = beta2 * nu + (1 - beta2) * jnp.square(g32)
        mhat = mu / bc1
        nhat = nu / bc2
        delta = mhat / (jnp.sqrt(nhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(opt_state["mu"])
    flat_nu = treedef.flatten_up_to(opt_state["nu"])
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, {"grad_norm": gnorm}
