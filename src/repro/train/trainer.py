"""Training loop: jitted AdamW step over any registered architecture, with
WSD/cosine schedules, packing-aware batches, and checkpointing."""
from __future__ import annotations

import functools
import time
from typing import Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.config.base import ModelConfig, TrainConfig
from repro.models import forward_train, init_params
from repro.train.optimizer import adamw_init, adamw_update
from repro.train.schedule import make_schedule


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    remat: bool = False) -> Callable:
    schedule = make_schedule(tcfg.schedule, tcfg.lr, tcfg.warmup_steps,
                             tcfg.total_steps, tcfg.stable_frac)

    def step_fn(params, opt_state, batch):
        def loss(p):
            l, metrics = forward_train(cfg, p, batch, remat=remat)
            return l, metrics
        (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
        lr = schedule(opt_state["step"])
        params, opt_state, om = adamw_update(
            params, grads, opt_state, lr,
            beta1=tcfg.beta1, beta2=tcfg.beta2, eps=tcfg.eps,
            weight_decay=tcfg.weight_decay, grad_clip=tcfg.grad_clip)
        metrics = dict(metrics)
        metrics.update(om)
        metrics["lr"] = lr
        return params, opt_state, metrics

    return step_fn


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig,
                 ckpt_dir: Optional[str] = None, remat: bool = False,
                 dtype=jnp.float32):
        self.cfg = cfg
        self.tcfg = tcfg
        self.ckpt_dir = ckpt_dir
        self.params = init_params(cfg, jax.random.PRNGKey(tcfg.seed), dtype)
        self.opt_state = adamw_init(self.params)
        self.step = 0
        self._fn = jax.jit(make_train_step(cfg, tcfg, remat),
                           donate_argnums=(0, 1))
        if ckpt_dir and latest_step(ckpt_dir) is not None:
            self.restore()

    def restore(self) -> None:
        tree = {"params": self.params, "opt": self.opt_state}
        tree, step, _ = load_checkpoint(self.ckpt_dir, tree)
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.step = step

    def save(self) -> None:
        if self.ckpt_dir:
            save_checkpoint(self.ckpt_dir, self.step,
                            {"params": self.params, "opt": self.opt_state})

    def fit(self, batches: Iterator[Dict], steps: int,
            log_every: int = 10, save_every: int = 0,
            log_fn: Callable[[str], None] = print) -> Dict:
        history = []
        t0 = time.monotonic()
        for _ in range(steps):
            batch = next(batches)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            self.params, self.opt_state, m = self._fn(
                self.params, self.opt_state, batch)
            self.step += 1
            if log_every and self.step % log_every == 0:
                ce = float(m["ce"])
                history.append((self.step, ce))
                dt = time.monotonic() - t0
                log_fn(f"step {self.step:5d} ce={ce:.4f} "
                       f"loss={float(m['loss']):.4f} "
                       f"lr={float(m['lr']):.2e} "
                       f"gnorm={float(m['grad_norm']):.2f} "
                       f"({dt:.1f}s)")
            if save_every and self.step % save_every == 0:
                self.save()
        return {"history": history, "final_ce": history[-1][1] if history
                else None}
