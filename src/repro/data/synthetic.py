"""Synthetic language-model data with LEARNABLE structure.

A first-order Markov chain over the vocabulary with a sparse transition
matrix: each token has `branching` plausible successors. Cross-entropy of a
perfect model is log(branching) << log(vocab), so training-loss descent is a
meaningful signal in integration tests and the train example.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Optional

import numpy as np


class SyntheticLM:
    def __init__(self, vocab: int, branching: int = 4, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.vocab = vocab
        self.branching = branching
        # successor table: (vocab, branching)
        self.table = rng.integers(0, vocab, size=(vocab, branching))

    def sample_doc(self, length: int, rng: np.random.Generator) -> np.ndarray:
        out = np.empty(length, np.int64)
        tok = int(rng.integers(self.vocab))
        for i in range(length):
            out[i] = tok
            tok = int(self.table[tok, int(rng.integers(self.branching))])
        return out

    def optimal_ce(self) -> float:
        return float(np.log(self.branching))


def synthetic_batches(vocab: int, batch: int, seq_len: int,
                      branching: int = 4, seed: int = 0,
                      num_batches: Optional[int] = None) -> Iterator[Dict]:
    """Yields {tokens (B,S), targets (B,S)} numpy batches."""
    lm = SyntheticLM(vocab, branching, seed)
    rng = np.random.default_rng(seed + 1)
    i = 0
    while num_batches is None or i < num_batches:
        docs = np.stack([lm.sample_doc(seq_len + 1, rng)
                         for _ in range(batch)])
        yield {"tokens": docs[:, :-1].astype(np.int32),
               "targets": docs[:, 1:].astype(np.int32)}
        i += 1
