"""Sequence packing: fill fixed-length rows with variable-length documents,
emitting segment ids + per-segment positions (consumed by the models'
segment-aware attention masks — the same mechanism the serving engine uses
for packed varlen chunked prefill)."""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np


def pack_documents(docs: Sequence[np.ndarray], seq_len: int,
                   pad_id: int = 0) -> Dict[str, np.ndarray]:
    """Greedy first-fit packing. Returns tokens/targets/seg/positions arrays
    of shape (n_rows, seq_len). targets are next-token within each segment;
    the final token of each segment gets target -100 (ignored), as do pads.
    """
    rows: List[List[np.ndarray]] = []
    space: List[int] = []
    for d in docs:
        d = d[: seq_len]
        placed = False
        for i, s in enumerate(space):
            if len(d) <= s:
                rows[i].append(d)
                space[i] -= len(d)
                placed = True
                break
        if not placed:
            rows.append([d])
            space.append(seq_len - len(d))
    n = len(rows)
    tokens = np.full((n, seq_len), pad_id, np.int32)
    targets = np.full((n, seq_len), -100, np.int32)
    seg = np.full((n, seq_len), -1, np.int32)
    pos = np.zeros((n, seq_len), np.int32)
    for i, row in enumerate(rows):
        off = 0
        for j, d in enumerate(row):
            L = len(d)
            tokens[i, off:off + L] = d
            targets[i, off:off + L - 1] = d[1:]
            seg[i, off:off + L] = j
            pos[i, off:off + L] = np.arange(L)
            off += L
    return {"tokens": tokens, "targets": targets, "seg": seg,
            "positions": pos}
