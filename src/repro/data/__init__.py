from repro.data.synthetic import SyntheticLM, synthetic_batches
from repro.data.packing import pack_documents

__all__ = ["SyntheticLM", "synthetic_batches", "pack_documents"]
