"""Pytree checkpointing to .npz (path-keyed, structure-preserving).

Arrays are gathered to host before save; on load, the caller may re-shard
with jax.device_put(..., sharding). Atomic via temp-file rename.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    return str(p)


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[Dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    meta = {"step": step, "extra": extra or {}}
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".npz.tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, __meta__=json.dumps(meta), **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def load_checkpoint(directory: str, template: Any,
                    step: Optional[int] = None) -> Tuple[Any, int, Dict]:
    """Restore into the structure of `template` (shapes/dtypes validated)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(str(z["__meta__"]))
        flat = {k: z[k] for k in z.files if k != "__meta__"}
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path_keys, leaf in paths:
        key = "/".join(_path_str(p) for p in path_keys)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, meta["step"], meta.get("extra", {})
