"""deepseek-v3-671b [moe] — MLA + 1 shared + 256 routed top-8 + MTP.

61L, d_model=7168, 128 heads (MLA), vocab=129280. First 3 layers dense
(d_ff=18432); remaining 58 layers MoE with 256 routed experts (d_expert=2048,
sigmoid scoring, top-8, routed_scaling=2.5) + 1 shared expert. MLA:
q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64, v_head=128. MTP depth 1.
This is the model the SBS paper serves in production. [arXiv:2412.19437]
"""
from repro.config.base import (
    AttentionKind, LayerKind, MLAConfig, ModelConfig, MoEConfig, register_arch,
)


@register_arch("deepseek-v3-671b")
def make(reduced: bool = False) -> ModelConfig:
    if reduced:
        return ModelConfig(
            name="deepseek-v3-671b[reduced]", family="moe",
            num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
            d_ff=512, vocab_size=512,
            attention=AttentionKind.MLA,
            mla=MLAConfig(q_lora_rank=128, kv_lora_rank=64,
                          qk_nope_head_dim=32, qk_rope_head_dim=16,
                          v_head_dim=32),
            layer_pattern=(LayerKind.MOE,), dense_prefix=1,
            moe=MoEConfig(num_experts=4, top_k=2, d_expert=128,
                          num_shared=1, d_shared=128,
                          score_fn="sigmoid", routed_scaling=2.5, capacity_factor=8.0),
            mtp_depth=1, max_seq_len=1024,
            source="arXiv:2412.19437",
        )
    return ModelConfig(
        name="deepseek-v3-671b", family="moe",
        num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
        d_ff=18432, vocab_size=129280,
        attention=AttentionKind.MLA,
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128),
        layer_pattern=(LayerKind.MOE,), dense_prefix=3,
        moe=MoEConfig(num_experts=256, top_k=8, d_expert=2048,
                      num_shared=1, d_shared=2048,
                      score_fn="sigmoid", routed_scaling=2.5),
        mtp_depth=1, max_seq_len=32768,
        source="arXiv:2412.19437",
    )
