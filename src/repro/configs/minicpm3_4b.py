"""minicpm3-4b [dense] — MLA (multi-head latent attention).

62L, d_model=2560, 40 heads, d_ff=6400, vocab=73448. MLA dims follow the
model card: q_lora_rank=768, kv_lora_rank=256, qk_nope=64, qk_rope=32,
v_head=64. Decode KV cache stores only the compressed latent + rope key.
[hf:openbmb/MiniCPM3-4B]
"""
from repro.config.base import (
    AttentionKind, LayerKind, MLAConfig, ModelConfig, register_arch,
)


@register_arch("minicpm3-4b")
def make(reduced: bool = False) -> ModelConfig:
    if reduced:
        return ModelConfig(
            name="minicpm3-4b[reduced]", family="dense",
            num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
            d_ff=512, vocab_size=512,
            attention=AttentionKind.MLA,
            mla=MLAConfig(q_lora_rank=128, kv_lora_rank=64,
                          qk_nope_head_dim=32, qk_rope_head_dim=16,
                          v_head_dim=32),
            layer_pattern=(LayerKind.DENSE,),
            tie_embeddings=True, max_seq_len=512,
            source="hf:openbmb/MiniCPM3-4B",
        )
    return ModelConfig(
        name="minicpm3-4b", family="dense",
        num_layers=62, d_model=2560, num_heads=40, num_kv_heads=40,
        d_ff=6400, vocab_size=73448,
        attention=AttentionKind.MLA,
        mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                      qk_nope_head_dim=64, qk_rope_head_dim=32,
                      v_head_dim=64),
        layer_pattern=(LayerKind.DENSE,),
        tie_embeddings=True, max_seq_len=32768,
        source="hf:openbmb/MiniCPM3-4B",
    )
