"""mamba2-370m [ssm] — attention-free, SSD (state-space duality).

48L, d_model=1024, no attention, no MLP (the Mamba block IS the layer),
vocab=50280, ssm_state=128. Decode state is O(1) per request => long_500k
runs. [arXiv:2405.21060]
"""
from repro.config.base import (
    AttentionKind, LayerKind, ModelConfig, SSMConfig, register_arch,
)


@register_arch("mamba2-370m")
def make(reduced: bool = False) -> ModelConfig:
    if reduced:
        return ModelConfig(
            name="mamba2-370m[reduced]", family="ssm",
            num_layers=2, d_model=256, num_heads=0, num_kv_heads=0,
            d_ff=0, vocab_size=512,
            attention=AttentionKind.NONE,
            layer_pattern=(LayerKind.SSM,),
            ssm=SSMConfig(d_state=32, head_dim=32, expand=2, chunk_size=32),
            tie_embeddings=True, max_seq_len=1024,
            source="arXiv:2405.21060",
        )
    return ModelConfig(
        name="mamba2-370m", family="ssm",
        num_layers=48, d_model=1024, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=50280,
        attention=AttentionKind.NONE,
        layer_pattern=(LayerKind.SSM,),
        ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk_size=256),
        tie_embeddings=True, max_seq_len=1048576,
        source="arXiv:2405.21060",
    )
