"""h2o-danube-3-4b [dense] — llama+mistral mix with sliding-window attention.

24L, d_model=3840, 32 heads (GQA kv=8), d_ff=10240, vocab=32000.
SWA window 4096 bounds the decode KV cache (ring buffer) => long_500k runs.
[arXiv:2401.16818]
"""
from repro.config.base import AttentionKind, LayerKind, ModelConfig, register_arch


@register_arch("h2o-danube-3-4b")
def make(reduced: bool = False) -> ModelConfig:
    if reduced:
        return ModelConfig(
            name="h2o-danube-3-4b[reduced]", family="dense",
            num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
            d_ff=512, vocab_size=512,
            attention=AttentionKind.SWA, sliding_window=64,
            layer_pattern=(LayerKind.DENSE,),
            max_seq_len=512,
            source="arXiv:2401.16818",
        )
    return ModelConfig(
        name="h2o-danube-3-4b", family="dense",
        num_layers=24, d_model=3840, num_heads=32, num_kv_heads=8,
        d_ff=10240, vocab_size=32000,
        attention=AttentionKind.SWA, sliding_window=4096,
        layer_pattern=(LayerKind.DENSE,),
        max_seq_len=524288,
        source="arXiv:2401.16818",
    )
