"""internvl2-1b [vlm] — InternViT + Qwen2-0.5B-style LM backbone.

24L, d_model=896, 14 heads (GQA kv=2), d_ff=4864, vocab=151655. The ViT
vision encoder + MLP projector is STUBbed: ``input_specs`` feeds
(B, 256, d_model) patch embeddings prepended to the text sequence.
[arXiv:2404.16821]
"""
from repro.config.base import AttentionKind, LayerKind, ModelConfig, register_arch


@register_arch("internvl2-1b")
def make(reduced: bool = False) -> ModelConfig:
    if reduced:
        return ModelConfig(
            name="internvl2-1b[reduced]", family="vlm",
            num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
            d_ff=512, vocab_size=512,
            attention=AttentionKind.GQA,
            layer_pattern=(LayerKind.DENSE,),
            num_patch_tokens=16, max_seq_len=512,
            rope_theta=1_000_000.0,
            source="arXiv:2404.16821",
        )
    return ModelConfig(
        name="internvl2-1b", family="vlm",
        num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
        d_ff=4864, vocab_size=151655,
        attention=AttentionKind.GQA,
        layer_pattern=(LayerKind.DENSE,),
        num_patch_tokens=256, max_seq_len=32768,
        rope_theta=1_000_000.0,
        source="arXiv:2404.16821",
    )
