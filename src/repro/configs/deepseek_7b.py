"""deepseek-7b [dense] — plain llama-architecture dense model.

30L, d_model=4096, 32 heads (MHA: kv=32), d_ff=11008, vocab=102400.
[arXiv:2401.02954]
"""
from repro.config.base import AttentionKind, LayerKind, ModelConfig, register_arch


@register_arch("deepseek-7b")
def make(reduced: bool = False) -> ModelConfig:
    if reduced:
        return ModelConfig(
            name="deepseek-7b[reduced]", family="dense",
            num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
            d_ff=512, vocab_size=512,
            attention=AttentionKind.GQA,
            layer_pattern=(LayerKind.DENSE,),
            max_seq_len=512,
            source="arXiv:2401.02954",
        )
    return ModelConfig(
        name="deepseek-7b", family="dense",
        num_layers=30, d_model=4096, num_heads=32, num_kv_heads=32,
        d_ff=11008, vocab_size=102400,
        attention=AttentionKind.GQA,
        layer_pattern=(LayerKind.DENSE,),
        max_seq_len=32768,
        source="arXiv:2401.02954",
    )
