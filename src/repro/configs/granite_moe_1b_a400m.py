"""granite-moe-1b-a400m [moe] — fine-grained MoE, 32 experts top-8.

24L, d_model=1024, 16 heads (GQA kv=8), d_expert=512, vocab=49155.
[hf:ibm-granite/granite-3.0-1b-a400m-base]
"""
from repro.config.base import (
    AttentionKind, LayerKind, ModelConfig, MoEConfig, register_arch,
)


@register_arch("granite-moe-1b-a400m")
def make(reduced: bool = False) -> ModelConfig:
    if reduced:
        return ModelConfig(
            name="granite-moe-1b-a400m[reduced]", family="moe",
            num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
            d_ff=128, vocab_size=512,
            attention=AttentionKind.GQA,
            layer_pattern=(LayerKind.MOE,),
            moe=MoEConfig(num_experts=4, top_k=2, d_expert=128, capacity_factor=8.0),
            tie_embeddings=True, max_seq_len=512,
            source="hf:ibm-granite/granite-3.0-1b-a400m-base",
        )
    return ModelConfig(
        name="granite-moe-1b-a400m", family="moe",
        num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8,
        d_ff=512, vocab_size=49155,
        attention=AttentionKind.GQA,
        layer_pattern=(LayerKind.MOE,),
        moe=MoEConfig(num_experts=32, top_k=8, d_expert=512),
        tie_embeddings=True, max_seq_len=32768,
        source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    )
