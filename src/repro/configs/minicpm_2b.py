"""minicpm-2b [dense] — llama-like, trained with the WSD schedule.

40L, d_model=2304, 36 heads (MHA: kv=36), d_ff=5760, vocab=122753.
The WSD (warmup-stable-decay) schedule is implemented in repro.train.schedule.
[arXiv:2404.06395]
"""
from repro.config.base import AttentionKind, LayerKind, ModelConfig, register_arch


@register_arch("minicpm-2b")
def make(reduced: bool = False) -> ModelConfig:
    if reduced:
        return ModelConfig(
            name="minicpm-2b[reduced]", family="dense",
            num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
            d_ff=512, vocab_size=512,
            attention=AttentionKind.GQA,
            layer_pattern=(LayerKind.DENSE,),
            tie_embeddings=True, max_seq_len=512,
            source="arXiv:2404.06395",
        )
    return ModelConfig(
        name="minicpm-2b", family="dense",
        num_layers=40, d_model=2304, num_heads=36, num_kv_heads=36,
        d_ff=5760, vocab_size=122753,
        attention=AttentionKind.GQA,
        layer_pattern=(LayerKind.DENSE,),
        tie_embeddings=True, max_seq_len=32768,
        source="arXiv:2404.06395",
    )
