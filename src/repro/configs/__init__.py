"""Assigned architecture configs. Importing this package populates the
registry in repro.config.base (used by ``get_arch`` / ``--arch``)."""

from repro.configs import (  # noqa: F401
    whisper_large_v3,
    internvl2_1b,
    minicpm_2b,
    minicpm3_4b,
    jamba_v0_1_52b,
    h2o_danube_3_4b,
    deepseek_v3_671b,
    mamba2_370m,
    granite_moe_1b_a400m,
    deepseek_7b,
)

ASSIGNED = [
    "whisper-large-v3",
    "internvl2-1b",
    "minicpm-2b",
    "minicpm3-4b",
    "jamba-v0.1-52b",
    "h2o-danube-3-4b",
    "deepseek-v3-671b",
    "mamba2-370m",
    "granite-moe-1b-a400m",
    "deepseek-7b",
]
