"""whisper-large-v3 [audio] — encoder-decoder transformer backbone.

32L decoder + 32L encoder, d_model=1280, 20 heads (MHA: kv=20), d_ff=5120,
vocab=51866. The mel-spectrogram + conv feature extractor is STUBbed:
``input_specs`` feeds (B, 1500, d_model) frame embeddings directly to the
encoder (the one allowed stub). [arXiv:2212.04356]
"""
from repro.config.base import AttentionKind, LayerKind, ModelConfig, register_arch


@register_arch("whisper-large-v3")
def make(reduced: bool = False) -> ModelConfig:
    if reduced:
        return ModelConfig(
            name="whisper-large-v3[reduced]", family="audio",
            num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
            d_ff=512, vocab_size=512,
            attention=AttentionKind.GQA,
            layer_pattern=(LayerKind.DENSE,),
            is_encoder_decoder=True, num_encoder_layers=2,
            encoder_seq_len=64, max_seq_len=256,
            source="arXiv:2212.04356",
        )
    return ModelConfig(
        name="whisper-large-v3", family="audio",
        num_layers=32, d_model=1280, num_heads=20, num_kv_heads=20,
        d_ff=5120, vocab_size=51866,
        attention=AttentionKind.GQA,
        layer_pattern=(LayerKind.DENSE,),
        is_encoder_decoder=True, num_encoder_layers=32,
        encoder_seq_len=1500, max_seq_len=32768,
        source="arXiv:2212.04356",
    )
