"""jamba-v0.1-52b [hybrid] — Mamba + attention 1:7 interleave, MoE 16e top-2.

32L, d_model=4096, 32 heads (GQA kv=8), d_ff=14336, vocab=65536.
Jamba block structure (period 8): attention at offset 4, MoE at every other
layer (offset 1). Jamba-v0.1 uses Mamba-1 blocks (d_state=16); we implement
the SSM block with the Mamba2/SSD formulation (d_state=16 kept) — noted as a
hardware adaptation in DESIGN.md. [arXiv:2403.19887]
"""
from repro.config.base import (
    AttentionKind, LayerKind, ModelConfig, MoEConfig, SSMConfig, register_arch,
)

_PATTERN = (
    LayerKind.SSM, LayerKind.SSM_MOE, LayerKind.SSM, LayerKind.SSM_MOE,
    LayerKind.DENSE, LayerKind.SSM_MOE, LayerKind.SSM, LayerKind.SSM_MOE,
)


@register_arch("jamba-v0.1-52b")
def make(reduced: bool = False) -> ModelConfig:
    if reduced:
        return ModelConfig(
            name="jamba-v0.1-52b[reduced]", family="hybrid",
            num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
            d_ff=512, vocab_size=512,
            attention=AttentionKind.GQA,
            layer_pattern=(LayerKind.SSM_MOE, LayerKind.DENSE),
            moe=MoEConfig(num_experts=4, top_k=2, d_expert=512, capacity_factor=8.0),
            ssm=SSMConfig(d_state=16, head_dim=32, expand=2, chunk_size=32),
            max_seq_len=1024,
            source="arXiv:2403.19887",
        )
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=14336, vocab_size=65536,
        attention=AttentionKind.GQA,
        layer_pattern=_PATTERN,
        moe=MoEConfig(num_experts=16, top_k=2, d_expert=14336),
        ssm=SSMConfig(d_state=16, head_dim=64, expand=2, chunk_size=256),
        max_seq_len=524288,
        source="arXiv:2403.19887",
    )
