"""Attention math: flash-XLA online softmax vs naive reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.models.attention import (
    build_mask, decode_attention, flash_attention_xla, gqa_reference,
)


def _rand(key, shape, scale=0.5):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


@pytest.mark.parametrize("H,K", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("window", [0, 16])
def test_flash_matches_reference(H, K, window):
    B, S, hd = 2, 96, 32
    q = _rand(0, (B, S, H, hd))
    k = _rand(1, (B, S, K, hd))
    v = _rand(2, (B, S, K, hd))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    mask = build_mask(pos, pos, causal=True, window=window)
    o_ref = gqa_reference(q, k, v, mask)
    o_flash = flash_attention_xla(q, k, v, pos, pos, causal=True,
                                  window=window, block=32)
    assert np.abs(np.asarray(o_ref - o_flash)).max() < 1e-5


def test_flash_handles_nondivisible_block():
    B, S, H, hd = 1, 50, 2, 16      # 50 % 32 != 0 → padding path
    q, k, v = _rand(0, (B, S, H, hd)), _rand(1, (B, S, H, hd)), \
        _rand(2, (B, S, H, hd))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    mask = build_mask(pos, pos)
    o_ref = gqa_reference(q, k, v, mask)
    o_flash = flash_attention_xla(q, k, v, pos, pos, block=32)
    assert np.abs(np.asarray(o_ref - o_flash)).max() < 1e-5


def test_segment_isolation():
    B, S, H, hd = 1, 32, 2, 16
    q, k, v = _rand(0, (B, S, H, hd)), _rand(1, (B, S, H, hd)), \
        _rand(2, (B, S, H, hd))
    seg = jnp.asarray([[0] * 16 + [1] * 16])
    pos = jnp.asarray([list(range(16)) + list(range(16))])
    mask = build_mask(pos, pos, seg, seg)
    o = gqa_reference(q, k, v, mask)
    # segment 1 output must equal running segment 1 alone
    m1 = build_mask(pos[:, 16:], pos[:, 16:])
    o1 = gqa_reference(q[:, 16:], k[:, 16:], v[:, 16:], m1)
    assert np.abs(np.asarray(o[:, 16:] - o1)).max() < 1e-5


def test_padding_rows_produce_zero():
    B, S, H, hd = 1, 8, 2, 16
    q, k, v = _rand(0, (B, S, H, hd)), _rand(1, (B, S, H, hd)), \
        _rand(2, (B, S, H, hd))
    seg = jnp.asarray([[0] * 4 + [-1] * 4])
    pos = jnp.asarray([list(range(4)) + [0] * 4])
    mask = build_mask(pos, pos, seg, seg)
    o = gqa_reference(q, k, v, mask)
    assert np.abs(np.asarray(o[:, 4:])).max() == 0.0


def test_decode_attention_matches_full_row():
    B, S, H, K, hd = 2, 24, 4, 2, 16
    q1 = _rand(0, (B, 1, H, hd))
    kc = _rand(1, (B, S, K, hd))
    vc = _rand(2, (B, S, K, hd))
    pos = jnp.asarray([10, 23])
    kv_pos = jnp.where(jnp.arange(S)[None] <= pos[:, None],
                       jnp.arange(S)[None], -1)
    o = decode_attention(q1, kc, vc, kv_pos, pos)
    # oracle: full attention with single query row at position pos
    for b in range(B):
        n = int(pos[b]) + 1
        mask = build_mask(pos[b:b+1, None], kv_pos[b:b+1, :n])
        o_ref = gqa_reference(q1[b:b+1], kc[b:b+1, :n], vc[b:b+1, :n], mask)
        assert np.abs(np.asarray(o[b] - o_ref[0])).max() < 1e-5


@given(
    s=st.integers(8, 64),
    h=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2]),
    block=st.sampled_from([8, 16, 32]),
    window=st.sampled_from([0, 8]),
)
@settings(max_examples=20, deadline=None)
def test_flash_reference_property(s, h, g, block, window):
    B, hd = 1, 8
    H, K = h * g, h
    q = _rand(s, (B, s, H, hd))
    k = _rand(s + 1, (B, s, K, hd))
    v = _rand(s + 2, (B, s, K, hd))
    pos = jnp.broadcast_to(jnp.arange(s), (B, s))
    mask = build_mask(pos, pos, causal=True, window=window)
    o_ref = gqa_reference(q, k, v, mask)
    o_f = flash_attention_xla(q, k, v, pos, pos, causal=True, window=window,
                              block=block)
    assert np.abs(np.asarray(o_ref - o_f)).max() < 1e-4
