"""Radix-tree prefix cache (cache-aware PBAA support)."""
from _hypothesis_shim import given, settings, st

from repro.core.prefix_cache import PrefixCacheIndex, RadixTree


def test_basic_match_block_quantized():
    t = RadixTree(block=4)
    t.insert(tuple(range(10)))       # blocks [0..3],[4..7],[8,9]
    assert t.match(tuple(range(10))) == 10
    assert t.match(tuple(range(6))) == 4          # only full blocks match
    assert t.match((99, 98, 97)) == 0


def test_divergent_suffixes_share_prefix():
    t = RadixTree(block=2)
    t.insert((1, 2, 3, 4))
    t.insert((1, 2, 9, 9))
    assert t.match((1, 2, 3, 4)) == 4
    assert t.match((1, 2, 9, 9)) == 4
    assert t.match((1, 2, 5, 5)) == 2


def test_lru_eviction_under_budget():
    t = RadixTree(budget_tokens=8, block=4)
    t.insert((1, 2, 3, 4))
    t.insert((5, 6, 7, 8))
    t.match((1, 2, 3, 4))            # refresh first entry
    t.insert((9, 10, 11, 12))        # evicts the LRU leaf (5,6,7,8)
    assert t.size <= 8
    assert t.match((5, 6, 7, 8)) == 0
    assert t.match((1, 2, 3, 4)) == 4


def test_index_per_dp_isolation():
    idx = PrefixCacheIndex([0, 1], block=2)
    idx.insert(0, (1, 2, 3, 4))
    assert idx.match(0, (1, 2, 3, 4)) == 4
    assert idx.match(1, (1, 2, 3, 4)) == 0
    assert idx.match(0, (1, 2, 3, 4), limit=2) == 2


@given(seqs=st.lists(st.lists(st.integers(0, 9), min_size=1, max_size=32),
                     min_size=1, max_size=20))
@settings(max_examples=50, deadline=None)
def test_match_is_longest_common_block_prefix(seqs):
    t = RadixTree(block=4, budget_tokens=10 ** 9)
    inserted = [tuple(s) for s in seqs]
    for s in inserted:
        t.insert(s)
    for s in inserted:
        # oracle: longest block-quantized common prefix with any inserted seq
        best = 0
        for o in inserted:
            k = 0
            while (k + 4 <= min(len(s), len(o))
                   and s[k:k + 4] == o[k:k + 4]):
                k += 4
            tail = min(len(s), len(o)) - k
            if tail > 0 and s[k:] == o[k:k + len(s) - k] and len(s) <= len(o):
                # partial final block matches only if it was a stored block
                if len(o) - k <= 4 and s[k:] == o[k:]:
                    k += len(s) - k
            best = max(best, k)
        assert t.match(s) >= best - 4  # within one block of the oracle
        assert t.match(s) >= (len(s) // 4) * 0  # sanity
        assert t.match(s) <= len(s)
