"""Algorithm 3 — IQR-aware lexicographical decode scheduling."""
import pytest
from _hypothesis_shim import given, settings, st

from repro.core.decode_alloc import (
    iqr_safe_set, lex_compare, percentile, schedule_decode_batch,
    schedule_decode_immediate,
)
from repro.core.types import DecodeDPState, Request


def mk_units(kvs, batches=None):
    batches = batches or [0] * len(kvs)
    return [DecodeDPState(dp_id=i, instance_id=0, batch=b, kv_tokens=k)
            for i, (k, b) in enumerate(zip(kvs, batches))]


def mk_req(rid, in_len, out_len=10):
    return Request(rid=rid, arrival_time=0.0, input_len=in_len,
                   output_len=out_len)


def test_percentile_matches_numpy():
    import numpy as np
    for q in (25, 50, 75, 99):
        for vals in ([1], [3, 1, 2], list(range(10)), [5, 5, 5, 9]):
            assert percentile(vals, q) == pytest.approx(
                float(np.percentile(vals, q)))


def test_iqr_masks_outlier():
    units = mk_units([100, 110, 105, 120, 1000])   # last one is a straggler
    safe = iqr_safe_set(units, k=1.5)
    assert [u.dp_id for u in safe] == [0, 1, 2, 3]


def test_iqr_fallback_when_all_saturated():
    units = mk_units([100, 100])
    for u in units:
        u.kv_budget = 10             # everything over budget
    safe = iqr_safe_set(units)
    assert len(safe) == 2            # fallback: N_safe = N


def test_lexicographic_batch_first_kv_tiebreak():
    a = DecodeDPState(0, 0, batch=2, kv_tokens=10)
    b = DecodeDPState(1, 0, batch=3, kv_tokens=1)
    assert lex_compare(a, b)         # smaller batch wins despite bigger KV
    c = DecodeDPState(2, 0, batch=2, kv_tokens=5)
    assert lex_compare(c, a)         # tie on batch -> smaller KV


def test_fill_the_valley_longest_first():
    units = mk_units([0, 0])
    reqs = [mk_req(0, 100), mk_req(1, 900)]
    out = schedule_decode_batch(reqs, units)
    # the 900-token request is placed first (while space is abundant) and
    # the two end up on different units
    assert len(out) == 2


def test_outlier_unit_receives_nothing():
    units = mk_units([50, 60, 55, 10_000])
    reqs = [mk_req(i, 100) for i in range(6)]
    out = schedule_decode_batch(reqs, units)
    assert 3 not in out


def test_immediate_round_robin():
    units = mk_units([0, 0, 0])
    rr = [0]
    out = schedule_decode_immediate([mk_req(i, 10) for i in range(6)],
                                    units, "round_robin", rr)
    assert all(len(v) == 2 for v in out.values())


@given(
    kv0=st.lists(st.integers(0, 100_000), min_size=2, max_size=32),
    lens=st.lists(st.integers(1, 30_000), min_size=1, max_size=64),
)
@settings(max_examples=60, deadline=None)
def test_schedule_invariants(kv0, lens):
    units = mk_units(list(kv0))
    before_kv = sum(u.kv_tokens for u in units)
    reqs = [mk_req(i, l) for i, l in enumerate(lens)]
    out = schedule_decode_batch(reqs, units)
    # every request assigned exactly once
    assigned = [r.rid for v in out.values() for r in v]
    assert sorted(assigned) == sorted(r.rid for r in reqs)
    # state bookkeeping adds exactly the admitted KV
    after_kv = sum(u.kv_tokens for u in units)
    assert after_kv - before_kv == sum(lens)
    assert sum(u.batch for u in units) == len(lens)


@given(
    lens=st.lists(st.integers(100, 10_000), min_size=8, max_size=64),
    n=st.integers(2, 16),
)
@settings(max_examples=40, deadline=None)
def test_lex_beats_round_robin_on_joint_imbalance(lens, n):
    """IQR-lex never produces a worse MAX batch than round-robin, and its
    KV spread is no worse than round-robin's on average."""
    units_a = mk_units([0] * n)
    units_b = mk_units([0] * n)
    reqs_a = [mk_req(i, l) for i, l in enumerate(lens)]
    reqs_b = [mk_req(i, l) for i, l in enumerate(lens)]
    schedule_decode_batch(reqs_a, units_a)
    rr = [0]
    schedule_decode_immediate(reqs_b, units_b, "round_robin", rr)
    assert max(u.batch for u in units_a) <= max(u.batch for u in units_b)
