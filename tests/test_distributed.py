"""Distribution layer: sharding rules, annotations, EP shard_map MoE,
HLO analysis. Multi-device pieces run in a subprocess (device count must be
forced before jax initializes)."""
import os
import subprocess
import sys

import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import (
    analyze_hlo, parse_computations, _shape_bytes,
)


ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _sub(code: str, timeout: int = 420) -> str:
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout,
                         env={**os.environ, "PYTHONPATH": "src"}, cwd=ROOT)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_shape_bytes():
    assert _shape_bytes("f32[2,3]{1,0}") == 24
    assert _shape_bytes("(bf16[4]{0}, s32[2]{0})") == 16
    assert _shape_bytes("pred[]") == 0 or _shape_bytes("pred[]") == 1


def test_hlo_analysis_counts_loop_flops():
    """Trip-count-aware analyzer: a dot inside a while body with trip N
    counts N×."""
    hlo = """HloModule test, entry_computation_layout={()->f32[4,4]{1,0}}

%body (p: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %p = (s32[], f32[4,4]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[4,4]{1,0} get-tuple-element(%p), index=1
  %d = f32[4,4]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[4,4]{1,0}) tuple(%ni, %d)
}

%cond (p: (s32[], f32[4,4])) -> pred[] {
  %p = (s32[], f32[4,4]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(7)
  ROOT %c = pred[] compare(%i, %n), direction=LT
}

ENTRY %main () -> f32[4,4] {
  %zero = s32[] constant(0)
  %init = f32[4,4]{1,0} constant({...})
  %t0 = (s32[], f32[4,4]{1,0}) tuple(%zero, %init)
  %w = (s32[], f32[4,4]{1,0}) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[4,4]{1,0} get-tuple-element(%w), index=1
}
"""
    r = analyze_hlo(hlo)
    # one 4x4x4 dot (128 flops) x 7 iterations
    assert r["flops"] == pytest.approx(7 * 2 * 4 * 4 * 4)


def test_sharding_rules_divisibility_fallbacks():
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.config import get_arch
from repro.config.base import ParallelConfig
from repro.launch.mesh import make_test_mesh
from repro.distributed.sharding import param_pspecs
from repro.models import abstract_params

mesh = make_test_mesh(2, 4)
par = ParallelConfig()
# whisper: 20 heads % 4 == 0 -> head sharding OK on 4-way model axis
cfg = get_arch("whisper-large-v3", reduced=True)   # 4 heads % 4 == 0
specs = param_pspecs(cfg, mesh, par, abstract_params(cfg, jnp.bfloat16))
wq = specs["blocks"]["p0"]["attn"]["w_q"]
assert wq == P(None, None, "model", None), wq      # (R, D, H=4, hd) H@model
# internvl2 reduced kv=2: 2 % 4 != 0 -> kv heads replicated, q row-parallel ok
cfg2 = get_arch("internvl2-1b", reduced=True)
specs2 = param_pspecs(cfg2, mesh, par, abstract_params(cfg2, jnp.bfloat16))
wk = specs2["blocks"]["p0"]["attn"]["w_k"]
assert "model" not in str(wk[2]) if len(wk) > 2 else True
print("SPECS_OK")
"""
    assert "SPECS_OK" in _sub(code)


def test_moe_ep_shard_map_matches_reference():
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.config.base import MoEConfig
from repro.models.moe import init_moe_params, moe_block
from repro.models.moe_ep import moe_block_ep
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh(2, 4)
mc = MoEConfig(num_experts=8, top_k=2, d_expert=32, capacity_factor=8.0)
p = init_moe_params(jax.random.PRNGKey(0), 16, mc, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16)) * 0.5
o_ref, _ = moe_block(x, p, mc)
for ep in (("model",), ("data", "model")):
    o_ep, _ = jax.jit(lambda x, p: moe_block_ep(
        x, p, mc, mesh, ("data",), ep))(x, p)
    assert np.abs(np.asarray(o_ep - o_ref)).max() < 1e-5, ep
print("EP_OK")
"""
    assert "EP_OK" in _sub(code)


def test_annotate_noop_without_mesh():
    from repro.distributed.annotate import constrain
    x = jnp.ones((4, 4))
    assert constrain(x, "tokens", None) is x


def test_annotate_applies_under_mesh():
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from repro.launch.mesh import make_test_mesh
from repro.distributed.annotate import activate, constrain

mesh = make_test_mesh(2, 2)
with activate(mesh, {"tokens": ("data",), "model": "model"}):
    @jax.jit
    def f(x):
        return constrain(x * 2, "tokens", "model")
    y = f(jnp.ones((4, 8)))
    assert "data" in str(y.sharding)
    # non-divisible dim -> silently skipped
    @jax.jit
    def g(x):
        return constrain(x * 2, "tokens", None)
    g(jnp.ones((3, 8)))
print("ANN_OK")
"""
    assert "ANN_OK" in _sub(code)


def test_seq_parallel_fallback_constraint():
    """forward_full applies the attn_seq constraint when mapped."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from repro.config import get_arch
from repro.launch.mesh import make_test_mesh
from repro.distributed.annotate import activate
from repro.models import init_params
from repro.models.model import forward_full

mesh = make_test_mesh(2, 2)
cfg = get_arch("deepseek-7b", reduced=True)
params = init_params(cfg, jax.random.PRNGKey(0))
tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
x_ref, _, _, _ = forward_full(cfg, params, tokens)
with activate(mesh, {"tokens": ("data",), "model": "model",
                     "attn_seq": "model"}):
    x_sp = jax.jit(lambda p, t: forward_full(cfg, p, t)[0])(params, tokens)
import numpy as np
assert np.abs(np.asarray(x_sp - x_ref)).max() < 2e-3
print("SEQPAR_OK")
"""
    assert "SEQPAR_OK" in _sub(code)
