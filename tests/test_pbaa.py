"""Algorithm 2 — Prioritized Batch Allocation (water-filling bin packing)."""
import pytest
from _hypothesis_shim import given, settings, st

from repro.core.prefill_alloc import chunk_utilization, greedy_dispatch, pbaa
from repro.core.prefix_cache import PrefixCacheIndex
from repro.core.types import DPState, Request


def mk_dps(n, chunk=1000, inst=0):
    return [DPState(dp_id=i, instance_id=inst, c_chunk=chunk)
            for i in range(n)]


def mk_req(rid, length, arrival=0.0):
    return Request(rid=rid, arrival_time=arrival, input_len=length)


def test_water_filling_balances_load():
    dps = mk_dps(4, chunk=1000)
    reqs = [mk_req(i, l) for i, l in enumerate([900, 800, 500, 400, 300,
                                                200, 100, 100])]
    assign, q_next, over = pbaa([], reqs, dps)
    assert not q_next and not over
    loads = {d: sum(t for _, t in lst) for d, lst in assign.items()}
    # longest-first → max-capacity: loads end up near-uniform
    assert max(loads.values()) - min(loads.values()) <= 400
    assert sum(loads.values()) == 3300


def test_legacy_requests_dispatch_first():
    dps = mk_dps(1, chunk=100)
    old = mk_req(0, 100)
    old.wait_cycles = 3
    new = mk_req(1, 100)
    assign, q_next, _ = pbaa([old], [new], dps)
    granted = [r.rid for lst in assign.values() for r, _ in lst]
    assert granted == [0]            # phase 1 fills the chunk; new waits
    assert [r.rid for r in q_next] == [1]


def test_chunking_splits_long_request():
    dps = mk_dps(2, chunk=1000)
    req = mk_req(0, 3500)
    assign, q_next, _ = pbaa([], [req], dps)
    total = sum(t for lst in assign.values() for _, t in lst)
    assert total == 1000             # one chunk granted this cycle
    assert req.remaining_prefill == 2500
    assert req in q_next
    # pinned: the tail must continue on the SAME DP (its KV lives there)
    first_dp = req.assigned_dp
    for d in dps:
        d.u_flight = 0               # engine drained
    assign2, _, _ = pbaa(q_next, [], dps)
    assert list(assign2.keys()) == [first_dp]


def test_overload_triggers_flow_control():
    dps = mk_dps(1, chunk=10)
    dps[0].u_flight = 10             # saturated
    req = mk_req(0, 5)
    pend = [req]
    for _ in range(9):
        assign, pend, over = pbaa(pend, [], dps, n_limit=8)
        assert not assign
    assert over and over[0].rid == 0  # exceeded N_limit


def test_cache_aware_prefers_cache_hit_dp():
    dps = mk_dps(2, chunk=1000)
    cache = PrefixCacheIndex([0, 1], block=4)
    toks = tuple(range(64))
    cache.insert(1, toks)            # dp 1 holds this prefix
    req = Request(rid=0, arrival_time=0, input_len=64, tokens=toks)
    assign, _, _ = pbaa([], [req], dps, cache=cache)
    assert list(assign.keys()) == [1]
    (r, granted), = assign[1]
    assert granted == 0              # full cache hit: zero compute cost


def test_chunk_utilization_metric():
    dps = mk_dps(2, chunk=100)
    assign = {0: [(mk_req(0, 80), 80)], 1: [(mk_req(1, 70), 70)]}
    assert chunk_utilization(assign, dps) == pytest.approx(0.75)


@given(
    lengths=st.lists(st.integers(1, 5000), min_size=1, max_size=40),
    n_dp=st.integers(1, 8),
    chunk=st.integers(64, 4096),
)
@settings(max_examples=80, deadline=None)
def test_pbaa_invariants(lengths, n_dp, chunk):
    dps = mk_dps(n_dp, chunk=chunk)
    reqs = [mk_req(i, l) for i, l in enumerate(lengths)]
    assign, q_next, over = pbaa([], reqs, dps)
    # 1. no DP is granted more than its available chunk capacity
    for d, lst in assign.items():
        assert sum(t for _, t in lst) <= chunk
    # 2. token conservation: granted + remaining == total
    granted = {r.rid: 0 for r in reqs}
    for lst in assign.values():
        for r, t in lst:
            granted[r.rid] += t
    for r in reqs:
        assert granted[r.rid] + r.remaining_prefill == r.input_len
    # 3. every request is granted, queued, or flow-controlled
    ids = set(granted[r.rid] > 0 or r.remaining_prefill > 0 for r in reqs)
    assert set(r.rid for r in q_next) | set(r.rid for r in over) | {
        r.rid for r in reqs if r.remaining_prefill == 0} == {
        r.rid for r in reqs}


@given(
    lengths=st.lists(st.integers(1, 900), min_size=2, max_size=30),
    n_dp=st.integers(2, 8),
)
@settings(max_examples=60, deadline=None)
def test_water_filling_lpt_bound(lengths, n_dp):
    """Longest-first water-filling is greedy list scheduling: the max
    per-DP load obeys Graham's bound  makespan ≤ total/m + (1 − 1/m)·L_max."""
    chunk = 10 ** 9                  # capacity never binds
    dps = mk_dps(n_dp, chunk=chunk)
    reqs = [mk_req(i, l) for i, l in enumerate(lengths)]
    assign = {}
    greedy_dispatch(reqs, dps, assign)
    wf_max = max(sum(t for _, t in lst) for lst in assign.values())
    bound = sum(lengths) / n_dp + (1 - 1 / n_dp) * max(lengths)
    assert wf_max <= bound + 1e-9
