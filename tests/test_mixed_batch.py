"""The unified mixed-batch plane (Sarathi-style chunked-prefill
piggybacking + length-bucketed batch formation):

  * `mixed_step` property: a fused decode+prefill step over one paged
    pool is token-exact against the dense seed oracle — prefilling
    residents graduate into the decode rows mid-stream and every slot's
    token sequence matches batch-of-1 serial generation
  * `decode_mask` protection: a prefilling resident has a LIVE table
    row, so the decode half must not scribble into its pages or bump
    its cursor while it waits for its next chunk
  * the real unified server (RealSBSServer, mixed_batch=True) is
    token-exact vs the seed serial decode, for BOTH the piggyback plane
    and the disjoint ablation — the scheduling policy is unobservable
    in token content, only in latency
  * sim-plane SimUnifiedInstance invariants: token conservation over
    the budget split, the starvation bound (forced minimum grant after
    `starve_limit` fully-starved steps), and the disjoint ablation's
    decode stall semantics
  * length-bucketed batch formation in StaggeredBatchScheduler: class
    boundaries, one-class-per-dispatch, starvation rescue after
    `bucket_max_wait` losing cycles, padding accounting, and the
    bucket_size=0 seed behavior
"""
import random

import jax
import jax.numpy as jnp
import pytest

from repro.config import ServingConfig, get_arch
from repro.core.scheduler import StaggeredBatchScheduler
from repro.core.types import DecodeDPState, Request
from repro.models import (
    decode_step, init_cache, init_paged_cache, init_params, mixed_step,
    paged_decode_step, paged_prefill_step, prefill_chunk,
)
from repro.serving.cluster import PrefillClusterSim, build_state
from repro.serving.costmodel import CostModel
from repro.serving.engine import SimUnifiedInstance
from repro.serving.kv_pool import BlockPool, pad_block_table
from repro.serving.real_engine import EngineSpec
from repro.serving.server import RealSBSServer

pytestmark = pytest.mark.mixed

MAX_LEN = 96
BLOCK = 16
NBT = MAX_LEN // BLOCK
N_NEW = 5


@pytest.fixture(scope="module")
def tiny_dense():
    cfg = get_arch("deepseek-7b", reduced=True)   # dense: exact equivalence
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _chunked_prefill(cfg, params, ids, chunk=16):
    """The seed server's prefill algorithm: batch-1 chunked KV build."""
    cache = init_cache(cfg, 1, MAX_LEN)
    logits = None
    for i in range(0, len(ids), chunk):
        arr = jnp.asarray([ids[i:i + chunk]], jnp.int32)
        logits, cache = prefill_chunk(cfg, params, arr, cache)
    return int(jnp.argmax(logits[0])), cache


def _serial_decode(cfg, params, t0, cache, n):
    """The seed server's decode loop: batch-of-1, token by token."""
    toks = [t0]
    for _ in range(n - 1):
        lg, cache = decode_step(cfg, params,
                                jnp.asarray([[toks[-1]]], jnp.int32), cache)
        toks.append(int(jnp.argmax(lg[0])))
    return toks, cache


def _oracle(cfg, params, ids, n):
    t0, cache = _chunked_prefill(cfg, params, ids)
    return _serial_decode(cfg, params, t0, cache, n)[0]


def _stage_slot(pc, pool, slot, life):
    """Reserve lifetime pages and install a zeroed table row — exactly
    what RealUnifiedEngine._apply_joins does for a raw request."""
    ids = pool.alloc(pool.blocks_for(life))
    tab = jnp.asarray(pad_block_table(ids, NBT), jnp.int32)
    pc = dict(pc)
    pc["block_tab"] = pc["block_tab"].at[slot].set(tab)
    pc["cur"] = pc["cur"].at[slot].set(0)
    return pc, ids


# ---------------------------------------------------------------------------
# mixed_step: token-exact vs the dense serial oracle
# ---------------------------------------------------------------------------

@pytest.mark.paged
def test_mixed_step_token_exact_vs_serial(tiny_dense):
    """Two slots decode while a third prefills chunk-by-chunk INSIDE the
    same mixed_step calls, then graduates into the decode half; all
    three token streams must equal the dense batch-of-1 oracle."""
    cfg, params = tiny_dense
    rng = random.Random(0)
    prompts = [[rng.randrange(cfg.vocab_size) for _ in range(L)]
               for L in (23, 48, 37)]          # slot 1: 3 chunks of 16
    serial = [_oracle(cfg, params, p, N_NEW) for p in prompts]

    pool = BlockPool(18, BLOCK)
    pc = init_paged_cache(cfg, 3, 18, MAX_LEN, BLOCK)
    toks = {}
    next_tok = [0, 0, 0]
    for s in (0, 2):                           # decoding residents:
        pc, _ = _stage_slot(pc, pool, s, len(prompts[s]) + N_NEW)
        lg = None
        for i in range(0, len(prompts[s]), 16):
            arr = jnp.asarray([prompts[s][i:i + 16]], jnp.int32)
            lg, pc = paged_prefill_step(cfg, params, arr, pc, s)
        t0 = int(jnp.argmax(lg[0]))
        assert t0 == serial[s][0]              # paged prefill == oracle
        toks[s] = [t0]
        next_tok[s] = t0
    pc, _ = _stage_slot(pc, pool, 1, len(prompts[1]) + N_NEW)

    consumed = 0
    mask = [True, False, True]
    for _ in range(2 * N_NEW + len(prompts[1]) // 16 + 2):
        active = [s for s in toks if len(toks[s]) < N_NEW]
        if not active and consumed >= len(prompts[1]):
            break
        chunks = ()
        if consumed < len(prompts[1]):
            ids = prompts[1][consumed:consumed + 16]
            chunks = ((jnp.asarray([ids], jnp.int32), jnp.int32(1)),)
        lg, clg, pc = mixed_step(
            cfg, params, jnp.asarray([[t] for t in next_tok], jnp.int32),
            pc, chunks, decode_mask=jnp.asarray(mask))
        nxt = jnp.argmax(lg, axis=-1)
        for s in active:
            t = int(nxt[s])
            toks[s].append(t)
            next_tok[s] = t
        if chunks:
            consumed += len(ids)
            # cursor advanced by the prefill half only, decode masked off
            assert int(pc["cur"][1]) == consumed
            if consumed >= len(prompts[1]):   # graduation: first token
                t0 = int(jnp.argmax(clg[0][0]))
                toks[1] = [t0]
                next_tok[1] = t0
                mask[1] = True
    assert [toks[s] for s in range(3)] == serial


@pytest.mark.paged
def test_mixed_step_decode_mask_protects_prefilling_rows(tiny_dense):
    """A masked (prefilling) slot must come through a mixed decode step
    with its pages and cursor untouched — an unmasked decode would write
    a garbage token's KV into its reserved blocks."""
    cfg, params = tiny_dense
    rng = random.Random(2)
    ids = [rng.randrange(cfg.vocab_size) for _ in range(16)]

    pool = BlockPool(12, BLOCK)
    pc = init_paged_cache(cfg, 2, 12, MAX_LEN, BLOCK)
    # slot 0: a decoding resident with one block of history
    pc, _ = _stage_slot(pc, pool, 0, 16 + 4)
    lg, pc = paged_prefill_step(
        cfg, params, jnp.asarray([ids], jnp.int32), pc, 0)
    # slot 1: mid-prefill resident — one chunk written, more to come
    pc, held = _stage_slot(pc, pool, 1, 48)
    _, pc = paged_prefill_step(
        cfg, params, jnp.asarray([ids], jnp.int32), pc, 1)

    before_cur = int(pc["cur"][1])
    before_pos = pc["kv_pos"][jnp.asarray(held)]
    toks = jnp.asarray([[int(jnp.argmax(lg[0]))], [0]], jnp.int32)
    _, _, pc = mixed_step(cfg, params, toks, pc, (),
                          decode_mask=jnp.asarray([True, False]))
    assert int(pc["cur"][1]) == before_cur
    assert int(pc["cur"][0]) == 17            # active row did advance
    assert bool(jnp.array_equal(pc["kv_pos"][jnp.asarray(held)],
                                before_pos))


@pytest.mark.paged
def test_mixed_step_degenerates_to_paged_decode(tiny_dense):
    """No chunks, no mask: the fused step IS paged_decode_step."""
    cfg, params = tiny_dense
    rng = random.Random(3)
    ids = [rng.randrange(cfg.vocab_size) for _ in range(16)]
    pool = BlockPool(8, BLOCK)
    pc = init_paged_cache(cfg, 2, 8, MAX_LEN, BLOCK)
    pc, _ = _stage_slot(pc, pool, 0, 16 + 4)
    lg, pc = paged_prefill_step(
        cfg, params, jnp.asarray([ids], jnp.int32), pc, 0)
    toks = jnp.asarray([[int(jnp.argmax(lg[0]))], [0]], jnp.int32)
    ml, chunk_lg, mc = mixed_step(cfg, params, toks, dict(pc), ())
    dl, dc = paged_decode_step(cfg, params, toks, dict(pc))
    assert chunk_lg == ()
    assert bool(jnp.array_equal(jnp.argmax(ml, -1), jnp.argmax(dl, -1)))
    assert bool(jnp.array_equal(mc["cur"], dc["cur"]))


# ---------------------------------------------------------------------------
# Real unified server: token-exact end to end, piggyback AND disjoint
# ---------------------------------------------------------------------------

def _mk_requests(cfg, n=4, out_len=5, seed=0):
    rng = random.Random(seed)
    reqs = []
    for i in range(n):
        L = rng.randrange(16, 48)
        reqs.append(Request(
            rid=i, arrival_time=i * 0.02, input_len=L, output_len=out_len,
            tokens=tuple(rng.randrange(cfg.vocab_size) for _ in range(L))))
    return reqs


@pytest.fixture(scope="module")
def unified_scfg():
    return ServingConfig(
        num_prefill_instances=1, prefill_dp_per_instance=1,
        num_decode_instances=1, decode_dp_per_instance=2,
        chunk_size=16, t_default=0.05, l_net=0.001,
        max_batch_per_dp=4, block_size=BLOCK,
        mixed_batch=True, mixed_chunk=32)


@pytest.fixture(scope="module")
def unified_spec(tiny_dense, unified_scfg):
    cfg, params = tiny_dense
    return EngineSpec(cfg, params, max_len=MAX_LEN, max_batch=4, max_new=5,
                      block_size=BLOCK,
                      decode_slots=unified_scfg.resolved_decode_slots)


@pytest.mark.parametrize("piggyback", [True, False])
def test_real_unified_serve_matches_serial_oracle(tiny_dense, unified_scfg,
                                                  unified_spec, piggyback):
    import dataclasses

    cfg, params = tiny_dense
    reqs = _mk_requests(cfg, seed=5)
    scfg = dataclasses.replace(unified_scfg, mixed_piggyback=piggyback)
    srv = RealSBSServer(cfg, params, serving_cfg=scfg, scheduler="sbs-la",
                        max_len=MAX_LEN, max_new=5, spec=unified_spec)
    assert srv.engines == []                  # decode-pool-only deployment
    gens = srv.serve(reqs, timeout=120)

    assert sorted(g.rid for g in gens) == [r.rid for r in reqs]
    for g, r in zip(gens, reqs):
        assert g.tokens == _oracle(cfg, params, list(r.tokens), r.output_len)
    # every prompt token was prefilled ON the decode pool, no handoff
    assert (sum(e.prefill_tokens for e in srv.decode_engines)
            == sum(r.input_len for r in reqs))
    # device-side pools fully drained
    for e in srv.decode_engines:
        for st in e._dp.values():
            st.pool.check()
            assert st.pool.used_count == 0
            assert not st.occupied()


# ---------------------------------------------------------------------------
# Sim-plane SimUnifiedInstance invariants
# ---------------------------------------------------------------------------

COST = CostModel(get_arch("deepseek-7b"))


def _raw(rid, input_len, output_len):
    return Request(rid=rid, arrival_time=0.0, input_len=input_len,
                   output_len=output_len)


def _decoding(rid, output_len=50):
    r = _raw(rid, 100, output_len)
    r.remaining_prefill = 0
    return r


def _run_step(eng, states, now):
    d = eng.start_step(states, now)
    assert d is not None
    now += d
    fin = eng.finish_step(now, states)
    return now, fin


def test_sim_unified_conserves_and_completes():
    """A raw prompt prefills at `chunk` tokens per step, graduates with
    its first token, decodes to completion: token conservation over the
    budget split, deterministic step count."""
    states = [DecodeDPState(dp_id=0, instance_id=0)]
    eng = SimUnifiedInstance(0, [0], COST, chunk=100)
    r = _raw(0, 250, 3)
    states[0].admit(r.input_len, reserve_len=r.input_len + r.output_len)
    eng.admit(0, r)
    assert eng.prefill_backlog() == 250

    now, done = 0.0, []
    while eng.has_work():
        now, fin = _run_step(eng, states, now)
        done.extend(fin)
    # 3 prefill steps (100+100+50, the last emits token #1) + 2 decode
    assert eng.steps == 5
    assert eng.prefill_tokens == 250
    assert done == [r] and r.generated == 3
    assert r.prefill_start is not None
    assert r.prefill_start <= r.first_token_time <= r.finish_time
    assert states[0].batch == 0               # KV released on finish


def test_sim_unified_starvation_bound_forces_grant():
    """Decode rows that exhaust the whole budget starve prefill for at
    most `starve_limit` steps; then a minimum grant is forced."""
    states = [DecodeDPState(dp_id=0, instance_id=0)]
    eng = SimUnifiedInstance(0, [0], COST, chunk=4, starve_limit=3)
    for i in range(4):                        # budget = 4 - 4 rows = 0
        rr = _decoding(i)
        states[0].admit(rr.input_len, reserve_len=150)
        eng.admit(0, rr)
    p = _raw(9, 8, 2)
    states[0].admit(p.input_len, reserve_len=10)
    eng.admit(0, p)

    now = 0.0
    for step in range(1, 4):
        now, _ = _run_step(eng, states, now)
        if step < 3:
            assert eng.prefill_tokens == 0    # starving, no grant yet
    assert eng.forced_grants == 1
    assert eng.prefill_tokens == max(1, 4 // 4)


def test_sim_unified_disjoint_stalls_decode():
    """piggyback=False is the prefill-prioritizing ablation: a step with
    pending prefill runs ONLY the chunk and the resident decode row
    emits nothing — the ITL bubble the unified plane removes."""
    states = [DecodeDPState(dp_id=0, instance_id=0)]
    eng = SimUnifiedInstance(0, [0], COST, chunk=100, piggyback=False)
    d0 = _decoding(0, output_len=5)
    states[0].admit(d0.input_len, reserve_len=105)
    eng.admit(0, d0)
    p = _raw(1, 60, 2)
    states[0].admit(p.input_len, reserve_len=62)
    eng.admit(0, p)

    now, _ = _run_step(eng, states, 0.0)
    assert d0.generated == 0                  # stalled behind the chunk
    assert p.generated == 1                   # prompt finished prefilling
    now, _ = _run_step(eng, states, now)
    assert d0.generated == 1                  # resumes next step


def test_sim_unified_piggyback_decode_never_stalls():
    """Same traffic as the disjoint test, piggyback on: the decode row
    emits EVERY step, including the one carrying the prefill chunk."""
    states = [DecodeDPState(dp_id=0, instance_id=0)]
    eng = SimUnifiedInstance(0, [0], COST, chunk=100, piggyback=True)
    d0 = _decoding(0, output_len=5)
    states[0].admit(d0.input_len, reserve_len=105)
    eng.admit(0, d0)
    p = _raw(1, 60, 2)
    states[0].admit(p.input_len, reserve_len=62)
    eng.admit(0, p)

    _run_step(eng, states, 0.0)
    assert d0.generated == 1                  # decode rode the mixed step
    assert p.generated == 1


# ---------------------------------------------------------------------------
# Length-bucketed batch formation (StaggeredBatchScheduler)
# ---------------------------------------------------------------------------

def _bucket_sched(bucket_size, bucket_max_wait=4):
    scfg = ServingConfig(num_prefill_instances=1, prefill_dp_per_instance=2,
                         chunk_size=3072)
    return StaggeredBatchScheduler(build_state(scfg),
                                   bucket_size=bucket_size,
                                   bucket_max_wait=bucket_max_wait)


def _preq(rid, n):
    return Request(rid=rid, arrival_time=0.0, input_len=n)


def test_length_class_boundaries():
    sched = _bucket_sched(512)
    for n, cls in ((1, 1), (512, 1), (513, 2), (1024, 2), (1025, 3)):
        assert sched._length_class(_preq(0, n)) == cls


def test_select_bucket_one_class_per_dispatch():
    """One length class dispatches per cycle — the one with the most
    queued prompt tokens — and the rest are held back in order."""
    sched = _bucket_sched(512)
    sched.buffer = [_preq(0, 100), _preq(1, 200), _preq(2, 600),
                    _preq(3, 700), _preq(4, 4000)]
    got = sched._select_bucket()
    assert [r.rid for r in got] == [4]        # 4000 queued tokens wins
    assert len(sched.buffer) == 4             # others held back
    got = sched._select_bucket()
    assert sorted(r.rid for r in got) == [2, 3]
    got = sched._select_bucket()
    assert sorted(r.rid for r in got) == [0, 1]
    assert sched.buffer == []


def test_select_bucket_starvation_rescue():
    """A class that loses `bucket_max_wait` consecutive cycles wins the
    next one outright, even against a heavier class."""
    sched = _bucket_sched(512, bucket_max_wait=2)
    sched.buffer = [_preq(0, 10)]
    for i in range(2):                        # keeps losing on tokens...
        sched.buffer.append(_preq(100 + i, 5000))
        got = sched._select_bucket()
        assert [r.rid for r in got] == [100 + i]
    sched.buffer.append(_preq(200, 5000))
    got = sched._select_bucket()              # ...until starved-first wins
    assert [r.rid for r in got] == [0]


def test_padding_accounting_and_disabled_bucketing():
    """bucket_size=0 keeps the seed behavior (whole buffer per dispatch)
    and padding waste counts pad-to-batch-max over multi-prompt batches
    only; CostModel.padding_flops_wasted prices the same tokens."""
    sched = _bucket_sched(0)
    assert sched.bucket_size == 0
    sched._note_padding([_preq(0, 100), _preq(1, 300), _preq(2, 50)])
    assert sched.padding_tokens_wasted == (300 - 100) + (300 - 50)
    sched._note_padding([_preq(3, 999)])      # singleton: no padding
    assert sched.padding_tokens_wasted == 450
    assert COST.padding_flops_wasted([100, 300, 50]) == pytest.approx(
        COST.prefill_flops(450))
    assert COST.padding_flops_wasted([]) == 0.0


def test_bucketed_formation_reduces_padding_sim():
    """End-to-end through the prefill sim on heavy-tail lengths: the
    bucketed scheduler wastes strictly fewer padding tokens and actually
    uses the bucketed dispatch path."""
    from repro.serving.workload import HEAVY_TAIL, generate

    cfg = get_arch("deepseek-7b")
    wasted = {}
    for label, bs in (("unbucketed", 0), ("bucketed", 512)):
        scfg = ServingConfig(num_prefill_instances=2,
                             prefill_dp_per_instance=4, chunk_size=3072,
                             t_default=0.1, bucket_size=bs)
        reqs = generate(HEAVY_TAIL, qps=25, duration=3.0, seed=9)
        sim = PrefillClusterSim(cfg, scfg, scheduler="sbs")
        sim.run(reqs, 3.0)
        wasted[label] = sim.sched.padding_tokens_wasted
        if bs:
            assert sim.sched.bucket_dispatches > 0
    assert wasted["bucketed"] < wasted["unbucketed"]
