"""MoE: sort-based FLOP-honest dispatch vs dense oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.config.base import MoEConfig
from repro.models.moe import (
    aux_loss, init_moe_params, moe_block, moe_block_dense_reference, route,
)


@pytest.mark.parametrize("score", ["softmax", "sigmoid"])
@pytest.mark.parametrize("shared", [0, 1])
def test_sorted_dispatch_matches_dense_oracle(score, shared):
    mc = MoEConfig(num_experts=8, top_k=2, d_expert=32, num_shared=shared,
                   d_shared=16, capacity_factor=8.0, score_fn=score,
                   routed_scaling=1.5 if score == "sigmoid" else 1.0)
    p = init_moe_params(jax.random.PRNGKey(0), 16, mc, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 10, 16)) * 0.5
    o1, l1 = moe_block(x, p, mc)
    o2, l2 = moe_block_dense_reference(x, p, mc)
    assert np.abs(np.asarray(o1 - o2)).max() < 1e-5
    assert abs(float(l1 - l2)) < 1e-6


def test_capacity_dropping_is_graceful():
    mc = MoEConfig(num_experts=4, top_k=2, d_expert=8, capacity_factor=0.25)
    p = init_moe_params(jax.random.PRNGKey(0), 16, mc, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 16))
    o, _ = moe_block(x, p, mc)
    assert o.shape == x.shape
    assert bool(jnp.isfinite(o).all())


def test_router_weights_normalized():
    mc = MoEConfig(num_experts=8, top_k=3, d_expert=8)
    p = init_moe_params(jax.random.PRNGKey(0), 16, mc, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (20, 16))
    w, e, probs = route(x, p, mc)
    assert np.allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-5)
    assert int(e.max()) < 8


def test_aux_loss_uniform_is_one():
    """Perfectly balanced routing gives aux ≈ 1 (E · Σ (1/E)·(1/E) · E)."""
    E, T, k = 4, 1000, 1
    probs = jnp.full((T, E), 1.0 / E)
    top_e = jnp.arange(T)[:, None] % E
    assert float(aux_loss(probs, top_e, E)) == pytest.approx(1.0, rel=1e-3)


def test_aux_loss_collapsed_is_e():
    E, T = 4, 256
    probs = jnp.zeros((T, E)).at[:, 0].set(1.0)
    top_e = jnp.zeros((T, 1), jnp.int32)
    assert float(aux_loss(probs, top_e, E)) == pytest.approx(float(E))


@given(t=st.integers(1, 40), e=st.sampled_from([2, 4, 8]),
       k=st.integers(1, 2), cf=st.floats(0.25, 2.0))
@settings(max_examples=25, deadline=None)
def test_moe_always_finite_and_shaped(t, e, k, cf):
    k = min(k, e)
    mc = MoEConfig(num_experts=e, top_k=k, d_expert=8, capacity_factor=cf)
    p = init_moe_params(jax.random.PRNGKey(0), 12, mc, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(t), (t, 12)) * 0.5
    o, laux = moe_block(x, p, mc)
    assert o.shape == x.shape
    assert bool(jnp.isfinite(o).all()) and bool(jnp.isfinite(laux))
