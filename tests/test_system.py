"""End-to-end system behaviour: the paper's claims reproduced in miniature.

These tests assert the MECHANISMS (HOL-blocking elimination, chunk-
utilization lift, joint decode balance) on scaled-down clusters so they run
in seconds; benchmarks/ runs the full-scale versions.
"""
import os
import subprocess
import sys

import pytest

from repro.config import ServingConfig, get_arch
from repro.serving.cluster import DecodeClusterSim, PrefillClusterSim
from repro.serving.workload import SHORT, WorkloadSpec, generate


CFG = get_arch("deepseek-v3-671b")


def _prefill_cfg(**kw):
    base = dict(num_prefill_instances=3, prefill_dp_per_instance=4,
                chunk_size=3072, t_default=0.1)
    base.update(kw)
    return ServingConfig(**base)


def test_sbs_eliminates_device_side_queueing():
    """§3.2: immediate dispatch piles requests in the engine (HOL); SBS
    shifts the queue to the scheduler side."""
    scfg = _prefill_cfg()
    r_imm = PrefillClusterSim(CFG, scfg, "immediate-rr").run(
        generate(SHORT, qps=50, duration=10, seed=0), 10)
    r_sbs = PrefillClusterSim(CFG, scfg, "sbs").run(
        generate(SHORT, qps=50, duration=10, seed=0), 10)
    assert r_imm.device_queue_mean > 5 * max(r_sbs.device_queue_mean, 1e-4)
    assert r_sbs.ttft_mean < r_imm.ttft_mean


def test_sbs_ttft_advantage_grows_with_load():
    scfg = _prefill_cfg()
    gains = []
    for qps in (40, 70):
        imm = PrefillClusterSim(CFG, scfg, "immediate-rr").run(
            generate(SHORT, qps=qps, duration=10, seed=1), 10)
        sbs = PrefillClusterSim(CFG, scfg, "sbs").run(
            generate(SHORT, qps=qps, duration=10, seed=1), 10)
        gains.append(1 - sbs.ttft_mean / imm.ttft_mean)
    assert all(g > 0.1 for g in gains)          # consistent TTFT win


def test_sbs_lifts_chunk_utilization():
    """Table 1 mechanism: bin-packing converts bubbles into utilization."""
    scfg = _prefill_cfg()
    qps = 70
    imm = PrefillClusterSim(CFG, scfg, "immediate-rr").run(
        generate(SHORT, qps=qps, duration=10, seed=2), 10)
    sbs = PrefillClusterSim(CFG, scfg, "sbs").run(
        generate(SHORT, qps=qps, duration=10, seed=2), 10)
    assert sbs.chunk_util > imm.chunk_util


def test_adaptive_interval_converges_online():
    scfg = _prefill_cfg(t_default=5.0)    # wildly wrong initial estimate
    sim = PrefillClusterSim(CFG, scfg, "sbs")
    sim.run(generate(SHORT, qps=50, duration=10, seed=3), 10)
    # Algorithm 1 must have pulled T̄_fwd down to the true pass-time regime
    assert sim.state.interval.t_fwd < 1.0


def test_flow_control_on_overload():
    scfg = _prefill_cfg(num_prefill_instances=1, prefill_dp_per_instance=1,
                        chunk_size=512, n_limit=3)
    reqs = generate(SHORT, qps=200, duration=5, seed=4)
    sim = PrefillClusterSim(CFG, scfg, "sbs")
    rep = sim.run(reqs, 5)
    assert rep.rejected > 0                # overload protection fired


def test_decode_iqr_lex_beats_round_robin_jointly():
    """Fig 7/8 mechanism at small scale: closed-loop decode; SBS balances
    both B_i and K_i, buying throughput."""
    scfg = ServingConfig(num_decode_instances=1, decode_dp_per_instance=16,
                         max_batch_per_dp=64, kv_budget_tokens=500_000)
    spec = WorkloadSpec("decode", 256, 16384, 2000.0, out_mean=200)
    N = 16 * 24

    def run(sched, pol):
        reqs = generate(spec, qps=10_000, duration=3, seed=5)[:4000]
        sim = DecodeClusterSim(CFG, scfg, scheduler=sched, policy=pol)
        return sim.run(reqs, 20, closed_loop=N)

    rr = run("immediate", "round_robin")
    sbs = run("sbs", "round_robin")
    assert sbs.throughput > rr.throughput
    assert sbs.batch_std_mean < rr.batch_std_mean


def test_watchdog_keeps_cluster_live():
    """Kill EndForward signals: SBS must not deadlock (safety path)."""
    from repro.core.scheduler import StaggeredBatchScheduler
    from repro.serving.cluster import build_state
    from repro.core.types import Request
    st = build_state(_prefill_cfg(t_default=0.1))
    sched = StaggeredBatchScheduler(st)
    sched.on_arrival(Request(rid=0, arrival_time=0, input_len=100), 0.0)
    cmds = sched.poll(0.0)
    assert cmds
    # engine never reports back; watchdog (5·T̄) must re-open the instance
    sched.on_arrival(Request(rid=1, arrival_time=0.1, input_len=100), 0.1)
    later = 0.1 + 5 * st.interval.t_fwd + st.interval.interval + 0.01
    cmds2 = sched.poll(later)
    assert cmds2, "watchdog failed to restore liveness"


def test_real_server_end_to_end():
    """SBS control plane over REAL jitted model forwards (tiny model)."""
    import random
    import jax
    from repro.core.types import Request
    from repro.models import init_params
    from repro.serving.server import RealSBSServer
    cfg = get_arch("granite-moe-1b-a400m", reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = random.Random(0)
    reqs = []
    for i in range(4):
        L = rng.randrange(16, 48)
        reqs.append(Request(
            rid=i, arrival_time=i * 0.02, input_len=L, output_len=3,
            tokens=tuple(rng.randrange(cfg.vocab_size) for _ in range(L))))
    srv = RealSBSServer(cfg, params, max_len=96, max_new=3)
    gens = srv.serve(reqs, timeout=300)
    assert len(gens) == 4
    assert all(len(g.tokens) == 3 for g in gens)


def test_dryrun_lowers_on_forced_device_mesh():
    """Sharding rules produce a valid lower+compile on a multi-device host
    (subprocess: device count must be forced before jax import)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp
from repro.config import get_arch
from repro.config.base import ParallelConfig, INPUT_SHAPES
from repro.launch.mesh import make_test_mesh
from repro.launch.specs import make_step_fn, batch_inputs
from repro.distributed.sharding import param_pspecs, batch_pspecs, named
from repro.models import abstract_params

cfg = get_arch("granite-moe-1b-a400m", reduced=True)
mesh = make_test_mesh(2, 4)
par = ParallelConfig(expert_axes=("model",))
shape = dataclasses.replace(INPUT_SHAPES["prefill_32k"], seq_len=64,
                            global_batch=4)
params = abstract_params(cfg, jnp.bfloat16)
p_shard = named(mesh, param_pspecs(cfg, mesh, par, params))
ins = batch_inputs(cfg, shape, jnp.bfloat16)
b_shard = named(mesh, batch_pspecs(mesh, par, 4, ins))
fn, _ = make_step_fn(cfg, shape, remat=False)
jfn = jax.jit(fn, in_shardings=(p_shard, b_shard["tokens"]))
compiled = jfn.lower(params, ins["tokens"]).compile()
assert compiled.as_text()
print("LOWER_OK")
"""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=300,
                         env={**os.environ, "PYTHONPATH": "src"},
                         cwd=root)
    assert "LOWER_OK" in out.stdout, out.stderr[-2000:]
