"""End-to-end P/D-disaggregated pipeline simulation."""
from repro.config import ServingConfig, get_arch
from repro.serving.e2e import PDClusterSim
from repro.serving.workload import WorkloadSpec, generate


def _scfg():
    return ServingConfig(num_prefill_instances=2, prefill_dp_per_instance=4,
                         num_decode_instances=1, decode_dp_per_instance=8,
                         chunk_size=3072, t_default=0.5,
                         max_batch_per_dp=64, kv_budget_tokens=400_000)


def test_pipeline_completes_all_requests():
    cfg = get_arch("deepseek-v3-671b")
    spec = WorkloadSpec("e2e", 64, 2000, 800.0, out_mean=40)
    reqs = generate(spec, qps=20, duration=8, seed=0)
    sim = PDClusterSim(cfg, _scfg(), scheduler="sbs")
    rep = sim.run(reqs, 8, slo_e2e=30.0)
    assert rep.n_finished == len(reqs)
    assert rep.ttft_mean > 0 and rep.tpot_mean > 0
    # TTFT includes prefill + KV transfer, and precedes E2E completion
    assert rep.ttft_mean < rep.e2e_mean


def test_sbs_beats_immediate_end_to_end():
    cfg = get_arch("deepseek-v3-671b")
    spec = WorkloadSpec("e2e", 64, 2000, 800.0, out_mean=40)
    res = {}
    for sched in ("immediate", "sbs"):
        reqs = generate(spec, qps=35, duration=8, seed=1)
        rep = PDClusterSim(cfg, _scfg(), scheduler=sched).run(
            reqs, 8, slo_e2e=30.0)
        res[sched] = rep
    assert res["sbs"].ttft_mean < res["immediate"].ttft_mean


def test_kv_transfer_scales_with_input_len():
    cfg = get_arch("deepseek-v3-671b")
    sim = PDClusterSim(cfg, _scfg())
    from repro.core.types import Request
    short = Request(rid=0, arrival_time=0, input_len=100)
    long = Request(rid=1, arrival_time=0, input_len=10_000)
    assert sim._transfer_time(long) > sim._transfer_time(short)
