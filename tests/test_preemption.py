"""SLO-aware overload control: page-level preemption, priority classes,
and flow-control admission.

  * property: a paged resident that is preempted mid-generation
    (`paged_cache_take`), has its pages freed, and re-joins through the
    dense-paged join path must continue generating EXACTLY the tokens of
    the seed serial decode — swap-out is invisible to the sampled stream
  * engine: `RealDecodeEngine.preempt` parks a dense batch-1 cache on
    the handoff bus, returns the victim's pages to the pool
    (conservation), and the victim re-admits through the normal join
  * `free_kv_tokens` credits the binder-claimable shared prefix — the
    admission under-counting regression (shared pages are POINTED AT,
    never allocated, so they are headroom for a matching prompt)
  * victim policy: `select_victims` only evicts strictly-less-urgent
    residents, least progress first, and refuses partial coverage
  * sim plane: `ClusterRuntime` preemption under a priority-mixed
    overload parks and re-admits batch work — nobody starves
  * `FlowController`: per-request outcome stats (a request throttled N
    times then admitted counts ONCE), priority-tiered reject horizon
"""
import random

import jax.numpy as jnp
import pytest

from _hypothesis_shim import given, settings, st
from test_real_plane import (  # noqa: F401  (tiny_dense is a fixture)
    BLOCK, MAX_LEN, NBT, _chunked_prefill, _publish_handoffs,
    _serial_decode, tiny_dense,
)

from repro.config import ServingConfig, get_arch
from repro.core.decode_alloc import kv_footprint, select_victims
from repro.core.flow_control import FlowAction, FlowController
from repro.core.types import DecodeDPState, Request
from repro.models import (
    init_paged_cache, paged_cache_clear_slot, paged_cache_join,
    paged_cache_take, paged_decode_step,
)
from repro.serving.e2e import PDClusterSim
from repro.serving.kv_pool import BlockPool, pad_block_table
from repro.serving.real_engine import (
    EngineSpec, KVHandoffBus, RealDecodeEngine,
)

N_TOTAL = 6


# ---------------------------------------------------------------------------
# Preempt → re-admit is token-exact (cache surgery level)
# ---------------------------------------------------------------------------

@pytest.mark.paged
@given(plen=st.sampled_from([16, 32, 48]),
       k_pre=st.integers(0, 3),
       seed=st.integers(0, 10 ** 6))
@settings(max_examples=6, deadline=None)
def test_preempt_rejoin_token_exact(tiny_dense, plen, k_pre, seed):
    """Join paged → decode k steps → preempt (take + clear + free pages)
    → re-join the parked dense cache into a DIFFERENT slot with freshly
    allocated pages → finish.  The full stream must equal the seed
    serial decode of the unpreempted request."""
    cfg, params = tiny_dense
    rng = random.Random(seed)
    ids = [rng.randrange(cfg.vocab_size) for _ in range(plen)]
    t0, cache = _chunked_prefill(cfg, params, ids)
    serial, _ = _serial_decode(cfg, params, t0, cache, N_TOTAL)

    pool = BlockPool(12, BLOCK)
    pc = init_paged_cache(cfg, 3, 12, MAX_LEN, BLOCK)
    need = pool.blocks_for(plen + N_TOTAL)
    blocks = pool.alloc(need)
    pc = paged_cache_join(
        cfg, pc, cache, 1,
        jnp.asarray(pad_block_table(blocks, NBT), jnp.int32))
    toks = [t0]
    nxt = [0, t0, 0]
    for _ in range(k_pre):
        lg, pc = paged_decode_step(
            cfg, params, jnp.asarray([[t] for t in nxt], jnp.int32), pc)
        t = int(jnp.argmax(lg[1]))
        toks.append(t)
        nxt[1] = t

    # page-level preemption: park as dense batch-1, give the pages back
    taken = paged_cache_take(cfg, pc, 1)
    pc = paged_cache_clear_slot(pc, 1)
    pool.free(blocks)
    pool.check()
    assert int(taken["cur"][0]) == plen + k_pre

    # re-admission: fresh pages, different slot, same join path
    blocks2 = pool.alloc(need)
    pc = paged_cache_join(
        cfg, pc, taken, 2,
        jnp.asarray(pad_block_table(blocks2, NBT), jnp.int32))
    nxt2 = [0, 0, toks[-1]]
    while len(toks) < N_TOTAL:
        lg, pc = paged_decode_step(
            cfg, params, jnp.asarray([[t] for t in nxt2], jnp.int32), pc)
        t = int(jnp.argmax(lg[2]))
        toks.append(t)
        nxt2[2] = t
    assert toks == serial
    pool.free(blocks2)
    pool.check()


# ---------------------------------------------------------------------------
# Engine-level preemption: parked state + pool conservation + re-admit
# ---------------------------------------------------------------------------

@pytest.mark.paged
def test_engine_preempt_frees_pages_and_readmits(tiny_dense):
    cfg, params = tiny_dense
    spec = EngineSpec(cfg, params, max_len=MAX_LEN, max_batch=4, max_new=4,
                      block_size=BLOCK)
    bus = KVHandoffBus()
    eng = RealDecodeEngine(0, [0], spec, bus)
    rng = random.Random(7)
    reqs = [Request(rid=i, arrival_time=0.0, input_len=24, output_len=4,
                    tokens=tuple(rng.randrange(cfg.vocab_size)
                                 for _ in range(24)),
                    priority=2 - 2 * i)          # rid0 batch, rid1 urgent
            for i in range(2)]
    _publish_handoffs(cfg, params, bus, reqs)
    dps = DecodeDPState(dp_id=0, instance_id=0, block_size=BLOCK)
    for r in reqs:
        eng.admit(0, r)
    eng._apply_joins(0.0, [dps])
    dp = eng._dp[0]
    per_req = dp.pool.blocks_for(24 + 4)
    free_joined = dp.pool.free_count

    # refused while a worker step is in flight
    eng.busy = True
    assert eng.preempt(0) is None
    eng.busy = False

    victim = eng.preempt(0)
    assert victim is reqs[0]
    assert dp.pool.free_count == free_joined + per_req     # pages returned
    assert 0 not in eng._slot_of
    assert all(r.rid != 0 for r in eng.running[0])
    parked = bus.gen(0).cache
    assert isinstance(parked, dict) and "kv_pos" in parked  # dense batch-1
    assert parked["kv_pos"].shape == (1, MAX_LEN)
    assert int(parked["cur"][0]) == 24                      # prefill KV intact
    dp.pool.check()

    # re-admission rides the normal deferred-join path
    eng.admit(0, reqs[0])
    eng._apply_joins(0.0, [dps])
    assert 0 in eng._slot_of
    assert dp.pool.free_count == free_joined

    # full conservation once both residents leave
    for r in reqs:
        eng.preempt(r.rid)
    assert dp.pool.free_count == free_joined + 2 * per_req
    dp.pool.check()


@pytest.mark.paged
def test_free_kv_tokens_credits_shared_prefix(tiny_dense):
    """The admission under-counting fix: a prompt whose block-aligned
    prefix is resident in the DP's binder must be credited those pages —
    they will be pointed at, never allocated."""
    cfg, params = tiny_dense
    spec = EngineSpec(cfg, params, max_len=MAX_LEN, max_batch=4, max_new=4,
                      block_size=BLOCK)
    eng = RealDecodeEngine(0, [0], spec, KVHandoffBus(), share_prefix=True)
    dp = eng._dp[0]
    rng = random.Random(11)
    prefix = tuple(rng.randrange(cfg.vocab_size) for _ in range(2 * BLOCK))
    blocks = dp.pool.alloc(2)
    dp.binder.insert(prefix, blocks, first_token=None)
    dp.pool.free(blocks)            # engine refs dropped; binder's remain
    base = dp.pool.free_count * BLOCK

    prompt = list(prefix) + [rng.randrange(cfg.vocab_size) for _ in range(8)]
    assert eng.free_kv_tokens(0) == base
    assert eng.free_kv_tokens(0, tokens=prompt) == base + 2 * BLOCK
    # a prompt with no resident prefix gets no credit
    cold = [rng.randrange(cfg.vocab_size) for _ in range(2 * BLOCK)]
    assert eng.free_kv_tokens(0, tokens=cold) == base


# ---------------------------------------------------------------------------
# Victim selection policy
# ---------------------------------------------------------------------------

def _resident(rid, prio, gen, arr=0.0):
    return Request(rid=rid, arrival_time=arr, input_len=32, output_len=16,
                   priority=prio, generated=gen)


def test_select_victims_strict_priority_least_progress():
    residents = [_resident(0, 0, 4), _resident(1, 2, 2),
                 _resident(2, 2, 10), _resident(3, 1, 1)]
    v = select_victims(residents, 16, block_size=BLOCK, max_priority=1)
    assert v and all(r.priority > 1 for r in v)      # strictly less urgent
    assert v[0].rid == 1                             # least progress first
    assert sum(kv_footprint(r, BLOCK) for r in v) >= 16


def test_select_victims_refuses_partial_coverage():
    residents = [_resident(1, 2, 2), _resident(2, 2, 10)]
    assert select_victims(residents, 10_000, block_size=BLOCK,
                          max_priority=0) == []
    # and nothing is eligible when every resident is at least as urgent
    assert select_victims([_resident(0, 0, 4)], 16, block_size=BLOCK,
                          max_priority=1) == []


# ---------------------------------------------------------------------------
# Sim-plane preemption: park + re-admit, starvation guard
# ---------------------------------------------------------------------------

def test_sim_preemption_parks_readmits_nobody_starves():
    """A priority-mixed overload on a tight decode pool: urgent arrivals
    force batch residents out; every victim must be re-admitted and run
    to completion (no starvation), and the pool must drain clean."""
    cfg = get_arch("deepseek-7b", reduced=True)
    scfg = ServingConfig(num_prefill_instances=1, prefill_dp_per_instance=2,
                         num_decode_instances=1, decode_dp_per_instance=1,
                         chunk_size=2048, t_default=0.05,
                         max_batch_per_dp=8, kv_budget_tokens=2_000,
                         preemption=True)
    hogs = [Request(rid=i, arrival_time=0.01 * i, input_len=400,
                    output_len=100, priority=2, slo_class="batch")
            for i in range(4)]
    urgent = [Request(rid=10 + i, arrival_time=0.1 + 0.05 * i, input_len=300,
                      output_len=4, priority=0, slo_class="interactive")
              for i in range(2)]
    reqs = hogs + urgent
    sim = PDClusterSim(cfg, scfg, scheduler="sbs-la")
    rep = sim.run(reqs, 10.0)

    assert rep.n_finished == len(reqs)
    for r in reqs:
        assert r.finish_time is not None, f"rid {r.rid} starved"
        assert r.generated == r.output_len
    assert sim.runtime.preempted, "tight pool + urgent arrivals must preempt"
    assert all(r.priority > 0 for r in sim.runtime.preempted)
    assert not sim.runtime._parked                   # everyone re-admitted
    for dp in sim.state.decode_dps:                  # pool drained clean
        assert dp.kv_occupancy == 0
        assert dp.batch == 0


# ---------------------------------------------------------------------------
# Flow-control stats: per-request outcomes, tiered reject horizon
# ---------------------------------------------------------------------------

def test_flow_stats_count_outcomes_not_cycles():
    fc = FlowController(n_limit=2, reject_after=3, backoff_base=0.01)
    r = Request(rid=1, arrival_time=0.0, input_len=8, output_len=1,
                priority=0)
    for _ in range(4):
        assert fc.gate(r, saturated=True) == FlowAction.THROTTLE
    assert fc.gate(r, saturated=False) == FlowAction.ADMIT
    s = fc.stats
    # throttled-then-admitted migrates buckets: ONE admitted, not 4+1
    assert (s.admitted, s.throttled, s.rejected) == (1, 0, 0)
    assert r.wait_cycles == 0        # admission resets the throttle clock


def test_flow_reject_horizon_tiered_by_priority():
    fc = FlowController(n_limit=2, reject_after=3, backoff_base=0.01)
    batch = Request(rid=2, arrival_time=0.0, input_len=8, output_len=1,
                    priority=2)
    acts = [fc.gate(batch, saturated=True) for _ in range(3)]
    assert acts == [FlowAction.THROTTLE, FlowAction.THROTTLE,
                    FlowAction.REJECT]               # horizon = n_limit × 1
    urgent = Request(rid=3, arrival_time=0.0, input_len=8, output_len=1,
                     priority=0)
    acts = [fc.gate(urgent, saturated=True) for _ in range(7)]
    assert acts[:6] == [FlowAction.THROTTLE] * 6     # n_limit × reject_after
    assert acts[6] == FlowAction.REJECT
    s = fc.stats
    assert (s.admitted, s.throttled, s.rejected) == (0, 0, 2)


def test_flow_backoff_doubles_and_caps():
    fc = FlowController(n_limit=2, backoff_base=0.05)
    assert fc.backoff(2) == pytest.approx(0.05)      # within grace: base
    assert fc.backoff(3) == pytest.approx(0.10)
    assert fc.backoff(4) == pytest.approx(0.20)
    assert fc.backoff(50) == pytest.approx(0.05 * 32)   # capped
