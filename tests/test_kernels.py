"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU), with
shape/dtype sweeps as required."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_prefill.ops import flash_prefill
from repro.kernels.flash_prefill.ref import flash_prefill_ref
from repro.kernels.ssd_scan.ops import ssd_chunk_kernel_apply
from repro.kernels.ssd_scan.ref import ssd_chunk_ref


def _rand(key, shape, dtype=jnp.float32, scale=0.5):
    x = jax.random.normal(jax.random.PRNGKey(key), shape) * scale
    return x.astype(dtype)


TOLS = {jnp.float32: 1e-5, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------------------
# flash_prefill
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,K,hd,bq,bk", [
    (1, 64, 2, 2, 32, 32, 32),
    (2, 64, 4, 2, 32, 16, 64),
    (1, 128, 8, 1, 16, 64, 32),
])
def test_flash_prefill_sweep(dtype, B, S, H, K, hd, bq, bk):
    q = _rand(0, (B, S, H, hd), dtype)
    k = _rand(1, (B, S, K, hd), dtype)
    v = _rand(2, (B, S, K, hd), dtype)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    seg = jnp.zeros((B, S), jnp.int32)
    o1 = flash_prefill(q, k, v, pos, pos, seg, seg, block_q=bq, block_kv=bk)
    o2 = flash_prefill_ref(q, k, v, pos, pos, seg, seg)
    assert np.abs(np.asarray(o1 - o2, np.float32)).max() < TOLS[dtype]


def test_flash_prefill_packed_varlen_with_padding():
    """The paper's C_chunk case: multiple segments + padding in one chunk."""
    B, S, H, K, hd = 2, 64, 4, 2, 32
    q, k, v = (_rand(i, (B, S, H, hd)) for i in range(3))
    pos = jnp.tile(jnp.concatenate(
        [jnp.arange(24), jnp.arange(30), jnp.zeros(10, jnp.int32)]), (B, 1))
    seg = jnp.tile(jnp.concatenate(
        [jnp.zeros(24, jnp.int32), jnp.ones(30, jnp.int32),
         -jnp.ones(10, jnp.int32)]), (B, 1))
    o1 = flash_prefill(q, k, v, pos, pos, seg, seg, block_q=32, block_kv=32)
    o2 = flash_prefill_ref(q, k, v, pos, pos, seg, seg)
    assert np.abs(np.asarray(o1 - o2)).max() < 1e-5
    assert np.abs(np.asarray(o1[:, 54:])).max() == 0.0   # padding rows zero


def test_flash_prefill_sliding_window():
    B, S, H, K, hd = 1, 64, 2, 2, 32
    q, k, v = (_rand(i, (B, S, H, hd)) for i in range(3))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    seg = jnp.zeros((B, S), jnp.int32)
    o1 = flash_prefill(q, k, v, pos, pos, seg, seg, window=8,
                       block_q=32, block_kv=32)
    o2 = flash_prefill_ref(q, k, v, pos, pos, seg, seg, window=8)
    assert np.abs(np.asarray(o1 - o2)).max() < 1e-5


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,K,hd,bk", [
    (2, 128, 8, 2, 64, 32),
    (3, 64, 4, 4, 32, 64),
    (1, 256, 16, 1, 16, 128),
])
def test_decode_attention_sweep(dtype, B, S, H, K, hd, bk):
    q = _rand(0, (B, H, hd), dtype)
    kc = _rand(1, (B, S, K, hd), dtype)
    vc = _rand(2, (B, S, K, hd), dtype)
    pos = jnp.asarray([min(5 + 61 * b, S - 1) for b in range(B)])
    kv_pos = jnp.where(jnp.arange(S)[None] <= pos[:, None],
                       jnp.arange(S)[None], -1)
    o1 = decode_attention(q, kc, vc, kv_pos, pos, block_kv=bk)
    o2 = decode_attention_ref(q, kc, vc, kv_pos, pos)
    assert np.abs(np.asarray(o1 - o2, np.float32)).max() < TOLS[dtype]


def test_decode_attention_window_ring():
    B, S, H, K, hd = 2, 64, 4, 2, 32
    q = _rand(0, (B, H, hd))
    kc, vc = _rand(1, (B, S, K, hd)), _rand(2, (B, S, K, hd))
    pos = jnp.asarray([40, 63])
    kv_pos = jnp.where(jnp.arange(S)[None] <= pos[:, None],
                       jnp.arange(S)[None], -1)
    o1 = decode_attention(q, kc, vc, kv_pos, pos, window=16, block_kv=32)
    o2 = decode_attention_ref(q, kc, vc, kv_pos, pos, window=16)
    assert np.abs(np.asarray(o1 - o2)).max() < 1e-5


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,nc,Q,nh,hp,ds", [
    (2, 3, 16, 4, 32, 16),
    (1, 2, 32, 2, 16, 8),
    (1, 1, 64, 8, 64, 32),
])
def test_ssd_chunk_sweep(dtype, B, nc, Q, nh, hp, ds):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = (jax.random.normal(ks[0], (B, nc, Q, nh, hp)) * 0.3).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, nc, Q, nh)))
    A = -jnp.exp(jnp.linspace(0.0, 1.0, nh))
    Bm = (jax.random.normal(ks[2], (B, nc, Q, ds)) * 0.3).astype(dtype)
    Cm = (jax.random.normal(ks[3], (B, nc, Q, ds)) * 0.3).astype(dtype)
    y1, s1 = ssd_chunk_kernel_apply(x, dt, A, Bm, Cm)
    y2, s2 = ssd_chunk_ref(x, dt, A, Bm, Cm)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    assert np.abs(np.asarray(y1 - y2)).max() < tol
    assert np.abs(np.asarray(s1 - s2)).max() < tol
