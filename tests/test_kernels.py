"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU), with
shape/dtype sweeps as required."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import (
    decode_attention, paged_decode_attention,
)
from repro.kernels.decode_attention.ref import (
    decode_attention_ref, paged_decode_attention_ref,
)
from repro.kernels.flash_prefill.ops import flash_prefill
from repro.kernels.flash_prefill.ref import flash_prefill_ref
from repro.kernels.ssd_scan.ops import ssd_chunk_kernel_apply
from repro.kernels.ssd_scan.ref import ssd_chunk_ref


def _rand(key, shape, dtype=jnp.float32, scale=0.5):
    x = jax.random.normal(jax.random.PRNGKey(key), shape) * scale
    return x.astype(dtype)


TOLS = {jnp.float32: 1e-5, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------------------
# flash_prefill
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,K,hd,bq,bk", [
    (1, 64, 2, 2, 32, 32, 32),
    (2, 64, 4, 2, 32, 16, 64),
    (1, 128, 8, 1, 16, 64, 32),
])
def test_flash_prefill_sweep(dtype, B, S, H, K, hd, bq, bk):
    q = _rand(0, (B, S, H, hd), dtype)
    k = _rand(1, (B, S, K, hd), dtype)
    v = _rand(2, (B, S, K, hd), dtype)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    seg = jnp.zeros((B, S), jnp.int32)
    o1 = flash_prefill(q, k, v, pos, pos, seg, seg, block_q=bq, block_kv=bk)
    o2 = flash_prefill_ref(q, k, v, pos, pos, seg, seg)
    assert np.abs(np.asarray(o1 - o2, np.float32)).max() < TOLS[dtype]


def test_flash_prefill_packed_varlen_with_padding():
    """The paper's C_chunk case: multiple segments + padding in one chunk."""
    B, S, H, K, hd = 2, 64, 4, 2, 32
    q, k, v = (_rand(i, (B, S, H, hd)) for i in range(3))
    pos = jnp.tile(jnp.concatenate(
        [jnp.arange(24), jnp.arange(30), jnp.zeros(10, jnp.int32)]), (B, 1))
    seg = jnp.tile(jnp.concatenate(
        [jnp.zeros(24, jnp.int32), jnp.ones(30, jnp.int32),
         -jnp.ones(10, jnp.int32)]), (B, 1))
    o1 = flash_prefill(q, k, v, pos, pos, seg, seg, block_q=32, block_kv=32)
    o2 = flash_prefill_ref(q, k, v, pos, pos, seg, seg)
    assert np.abs(np.asarray(o1 - o2)).max() < 1e-5
    assert np.abs(np.asarray(o1[:, 54:])).max() == 0.0   # padding rows zero


def test_flash_prefill_sliding_window():
    B, S, H, K, hd = 1, 64, 2, 2, 32
    q, k, v = (_rand(i, (B, S, H, hd)) for i in range(3))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    seg = jnp.zeros((B, S), jnp.int32)
    o1 = flash_prefill(q, k, v, pos, pos, seg, seg, window=8,
                       block_q=32, block_kv=32)
    o2 = flash_prefill_ref(q, k, v, pos, pos, seg, seg, window=8)
    assert np.abs(np.asarray(o1 - o2)).max() < 1e-5


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,K,hd,bk", [
    (2, 128, 8, 2, 64, 32),
    (3, 64, 4, 4, 32, 64),
    (1, 256, 16, 1, 16, 128),
])
def test_decode_attention_sweep(dtype, B, S, H, K, hd, bk):
    q = _rand(0, (B, H, hd), dtype)
    kc = _rand(1, (B, S, K, hd), dtype)
    vc = _rand(2, (B, S, K, hd), dtype)
    pos = jnp.asarray([min(5 + 61 * b, S - 1) for b in range(B)])
    kv_pos = jnp.where(jnp.arange(S)[None] <= pos[:, None],
                       jnp.arange(S)[None], -1)
    o1 = decode_attention(q, kc, vc, kv_pos, pos, block_kv=bk)
    o2 = decode_attention_ref(q, kc, vc, kv_pos, pos)
    assert np.abs(np.asarray(o1 - o2, np.float32)).max() < TOLS[dtype]


def test_decode_attention_window_ring():
    B, S, H, K, hd = 2, 64, 4, 2, 32
    q = _rand(0, (B, H, hd))
    kc, vc = _rand(1, (B, S, K, hd)), _rand(2, (B, S, K, hd))
    pos = jnp.asarray([40, 63])
    kv_pos = jnp.where(jnp.arange(S)[None] <= pos[:, None],
                       jnp.arange(S)[None], -1)
    o1 = decode_attention(q, kc, vc, kv_pos, pos, window=16, block_kv=32)
    o2 = decode_attention_ref(q, kc, vc, kv_pos, pos, window=16)
    assert np.abs(np.asarray(o1 - o2)).max() < 1e-5


@pytest.mark.paged
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,N,bs,nbt,H,K,hd", [
    (3, 16, 16, 4, 4, 2, 32),
    (2, 20, 32, 3, 8, 4, 16),
    (1, 6, 64, 2, 2, 1, 64),
])
def test_paged_decode_attention_sweep(dtype, B, N, bs, nbt, H, K, hd):
    """Block-table kernel (scalar-prefetched physical page ids) vs the
    dense block-gather oracle, including -1 (null-block) table entries
    and a polluted null block (its kv_pos must be unobservable)."""
    rng = np.random.default_rng(0)
    q = _rand(0, (B, H, hd), dtype)
    k_pool = _rand(1, (N, bs, K, hd), dtype)
    v_pool = _rand(2, (N, bs, K, hd), dtype)
    # each row owns a random prefix of nbt distinct non-null pages
    tabs = []
    free = list(range(1, N))
    rng.shuffle(free)
    for b in range(B):
        n_real = int(rng.integers(0, nbt + 1)) if b else nbt
        row = [free.pop() for _ in range(n_real)] + [-1] * (nbt - n_real)
        tabs.append(row)
    block_tab = jnp.asarray(tabs, jnp.int32)
    # kv_pos pool: valid ascending positions everywhere, INCLUDING the
    # null block (simulating inactive-row scribbles) — table masking must
    # hide it
    kv_pos_pool = jnp.broadcast_to(
        jnp.arange(bs, dtype=jnp.int32)[None], (N, bs)).copy()
    pos = jnp.asarray([bs - 1] * B, jnp.int32)
    o1 = paged_decode_attention(q, k_pool, v_pool, kv_pos_pool, block_tab,
                                pos)
    o2 = paged_decode_attention_ref(q, k_pool, v_pool, kv_pos_pool,
                                    block_tab, pos)
    assert np.abs(np.asarray(o1 - o2, np.float32)).max() < TOLS[dtype]


@pytest.mark.paged
def test_paged_decode_attention_matches_dense_gather():
    """Kernel == flat decode_attention over the materialised gather (the
    reference fallback the model path uses)."""
    B, N, bs, nbt, H, K, hd = 2, 10, 16, 3, 4, 2, 32
    q = _rand(0, (B, H, hd))
    k_pool = _rand(1, (N, bs, K, hd))
    v_pool = _rand(2, (N, bs, K, hd))
    block_tab = jnp.asarray([[1, 4, -1], [7, -1, -1]], jnp.int32)
    kv_pos_pool = jnp.broadcast_to(
        jnp.arange(bs, dtype=jnp.int32)[None], (N, bs)).copy()
    pos = jnp.asarray([bs - 1, 7], jnp.int32)
    o1 = paged_decode_attention(q, k_pool, v_pool, kv_pos_pool, block_tab,
                                pos)
    safe = jnp.maximum(block_tab, 0)
    kg = k_pool[safe].reshape(B, nbt * bs, K, hd)
    vg = v_pool[safe].reshape(B, nbt * bs, K, hd)
    kvg = jnp.where(block_tab[..., None] < 0, -1,
                    kv_pos_pool[safe]).reshape(B, nbt * bs)
    o2 = decode_attention(q, kg, vg, kvg, pos, block_kv=bs)
    assert np.abs(np.asarray(o1 - o2)).max() < 1e-5


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,nc,Q,nh,hp,ds", [
    (2, 3, 16, 4, 32, 16),
    (1, 2, 32, 2, 16, 8),
    (1, 1, 64, 8, 64, 32),
])
def test_ssd_chunk_sweep(dtype, B, nc, Q, nh, hp, ds):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = (jax.random.normal(ks[0], (B, nc, Q, nh, hp)) * 0.3).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, nc, Q, nh)))
    A = -jnp.exp(jnp.linspace(0.0, 1.0, nh))
    Bm = (jax.random.normal(ks[2], (B, nc, Q, ds)) * 0.3).astype(dtype)
    Cm = (jax.random.normal(ks[3], (B, nc, Q, ds)) * 0.3).astype(dtype)
    y1, s1 = ssd_chunk_kernel_apply(x, dt, A, Bm, Cm)
    y2, s2 = ssd_chunk_ref(x, dt, A, Bm, Cm)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    assert np.abs(np.asarray(y1 - y2)).max() < tol
    assert np.abs(np.asarray(s1 - s2)).max() < tol
