"""Serving-path equivalence: prefill + decode + chunked prefill must match
the full forward pass exactly, for every architecture family — this is the
invariant the whole serving engine rests on."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_arch, list_archs
from repro.models import decode_step, init_params, prefill
from repro.models.model import (
    forward_full, init_cache, logits_from_hidden, prefill_chunk,
)

ARCHS = list_archs()
TOL = 2e-3


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_matches_full(arch, model_setup):
    B, S, S0 = 2, 16, 10
    cfg, params, tokens, embeds, full, npre = model_setup(arch, B, S)
    lg, cache = prefill(cfg, params, tokens[:, :S0], embeds=embeds,
                        max_len=S + npre + 4)
    errs = [np.abs(np.asarray(lg - full[:, npre + S0 - 1])).max()]
    for t in range(S0, S):
        lg, cache = decode_step(cfg, params, tokens[:, t:t + 1], cache)
        errs.append(np.abs(np.asarray(lg - full[:, npre + t])).max())
    assert max(errs) < TOL


@pytest.mark.slow
@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if not get_arch(a, True).is_encoder_decoder])
def test_chunked_prefill_matches_full(arch, model_setup):
    """True chunked prefill (the paper's C_chunk unit) with KV continuation.
    S=16 matches test_prefill_then_decode_matches_full so the session-scoped
    model_setup cache is shared (one init+forward per arch, not two); C=4
    keeps ≥3 chunks so middle chunks (prior KV AND a later continuation)
    stay covered."""
    B, S, C = 2, 16, 4
    cfg, params, tokens, embeds, full, npre = model_setup(arch, B, S)
    if cfg.num_patch_tokens:
        lg, cache = prefill(cfg, params, tokens[:, :C], embeds=embeds,
                            max_len=64)
        errs = [np.abs(np.asarray(lg - full[:, npre + C - 1])).max()]
        start = C
    else:
        cache = init_cache(cfg, B, 64)
        errs, start = [], 0
    for c0 in range(start, S, C):
        lg, cache = prefill_chunk(cfg, params, tokens[:, c0:c0 + C], cache)
        errs.append(np.abs(np.asarray(lg - full[:, npre + c0 + C - 1])).max())
    assert max(errs) < TOL


def test_swa_ring_buffer_wraps_correctly():
    cfg = get_arch("h2o-danube-3-4b", reduced=True)
    cfg = dataclasses.replace(cfg, sliding_window=8)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S, S0 = 2, 40, 13             # prefill > window, decode wraps ring
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    x, _, _, _ = forward_full(cfg, params, tokens)
    full = logits_from_hidden(cfg, params, x)
    lg, cache = prefill(cfg, params, tokens[:, :S0], max_len=64)
    errs = [np.abs(np.asarray(lg - full[:, S0 - 1])).max()]
    for t in range(S0, S):
        lg, cache = decode_step(cfg, params, tokens[:, t:t + 1], cache)
        errs.append(np.abs(np.asarray(lg - full[:, t])).max())
    assert max(errs) < TOL


def test_variable_length_prefill_rows():
    cfg = get_arch("deepseek-7b", reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    x, _, _, _ = forward_full(cfg, params, tokens)
    full = logits_from_hidden(cfg, params, x)
    lengths = jnp.array([5, 12], jnp.int32)
    lg, cache = prefill(cfg, params, tokens, lengths=lengths, max_len=32)
    # per-row logits correspond to each row's own last valid position
    assert np.abs(np.asarray(lg[0] - full[0, 4])).max() < TOL
    assert np.abs(np.asarray(lg[1] - full[1, 11])).max() < TOL
    # and decode continues per-row at the right positions
    nxt = jnp.stack([tokens[0, 5:6], tokens[1, 11:12]])
    lg2, _ = decode_step(cfg, params, nxt, cache)
    assert np.abs(np.asarray(lg2[0] - full[0, 5])).max() < TOL


def test_packed_segments_are_isolated():
    """Packing two docs into one row (the varlen chunk!) must produce the
    same logits as running them separately."""
    cfg = get_arch("deepseek-7b", reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    d1 = jax.random.randint(jax.random.PRNGKey(1), (1, 7), 0, cfg.vocab_size)
    d2 = jax.random.randint(jax.random.PRNGKey(2), (1, 5), 0, cfg.vocab_size)
    packed = jnp.concatenate([d1, d2], axis=1)
    seg = jnp.asarray([[0] * 7 + [1] * 5])
    pos = jnp.asarray([list(range(7)) + list(range(5))])
    xp, _, _, _ = forward_full(cfg, params, packed, positions=pos, seg=seg)
    lp = logits_from_hidden(cfg, params, xp)
    x1, _, _, _ = forward_full(cfg, params, d1)
    l1 = logits_from_hidden(cfg, params, x1)
    x2, _, _, _ = forward_full(cfg, params, d2)
    l2 = logits_from_hidden(cfg, params, x2)
    assert np.abs(np.asarray(lp[:, :7] - l1)).max() < TOL
    assert np.abs(np.asarray(lp[:, 7:] - l2)).max() < TOL
