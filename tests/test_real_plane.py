"""The real engine plane behind the unified ClusterRuntime:

  * token-level equivalence of continuous batched decode (padded batch
    cache + join/leave AND the paged block-table cache) against the seed
    per-request serial decode
  * cache_take/cache_join round trip (the watchdog migration path), for
    both backends
  * paged admission: a DP admits by free-BLOCK count, sustaining more
    concurrent requests than the padded plane at equal KV memory
  * conservation + completion invariants of the real P/D handoff under
    `sbs` and `sbs-la`, including the satellite regressions:
      - prefill_start stamped when the first chunk STARTS (not at
        prefill completion)
      - serve() leaves caller-owned Request.arrival_time untouched
      - a failing worker forward (prefill OR decode) surfaces within one
        scheduling window, not at the timeout horizon
  * the cross-plane equivalence sweep (sim/real × padded/paged, @slow)
"""
import random
import time

import jax
import jax.numpy as jnp
import pytest

from repro.config import ServingConfig, get_arch
from repro.core.types import DecodeDPState, Request
from repro.models import (
    cache_join, cache_take, decode_step, init_cache, init_paged_cache,
    init_params, paged_cache_clear_slot, paged_cache_join, paged_cache_take,
    paged_decode_step, prefill_chunk,
)
from repro.serving.kv_pool import BlockPool, pad_block_table
from repro.serving.real_engine import (
    EngineSpec, KVHandoffBus, RealDecodeEngine,
)
from repro.serving.runtime import ClusterRuntime
from repro.serving.server import RealSBSServer

MAX_LEN = 96
BLOCK = 16
N_NEW = 5


@pytest.fixture(scope="module")
def tiny_dense():
    cfg = get_arch("deepseek-7b", reduced=True)   # dense: exact equivalence
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _chunked_prefill(cfg, params, ids, chunk=16):
    """The seed server's prefill algorithm: batch-1 chunked KV build."""
    cache = init_cache(cfg, 1, MAX_LEN)
    logits = None
    for i in range(0, len(ids), chunk):
        arr = jnp.asarray([ids[i:i + chunk]], jnp.int32)
        logits, cache = prefill_chunk(cfg, params, arr, cache)
    return int(jnp.argmax(logits[0])), cache


def _serial_decode(cfg, params, t0, cache, n):
    """The seed server's decode loop: batch-of-1, token by token."""
    toks = [t0]
    for _ in range(n - 1):
        lg, cache = decode_step(cfg, params,
                                jnp.asarray([[toks[-1]]], jnp.int32), cache)
        toks.append(int(jnp.argmax(lg[0])))
    return toks, cache


# ---------------------------------------------------------------------------
# Batched continuous decode == seed serial decode
# ---------------------------------------------------------------------------

def test_batched_continuous_decode_matches_serial(tiny_dense):
    """Requests joining a padded batch cache at different steps (continuous
    batching) must generate exactly the tokens of the seed per-request
    serial decode."""
    cfg, params = tiny_dense
    rng = random.Random(0)
    prompts = [[rng.randrange(cfg.vocab_size) for _ in range(L)]
               for L in (23, 37, 11)]
    serial, handoffs = [], []
    for ids in prompts:
        t0, cache = _chunked_prefill(cfg, params, ids)
        serial.append(_serial_decode(cfg, params, t0, cache, N_NEW)[0])
        handoffs.append((t0, cache))

    B = 4
    bc = init_cache(cfg, B, MAX_LEN)
    # join-on-handoff: r0/r1 up front, r2 joins two steps later
    toks = {}
    next_tok = [0] * B
    for slot, ridx in ((0, 0), (2, 1)):
        t0, cache = handoffs[ridx]
        bc = cache_join(bc, cache, slot)
        toks[slot] = [t0]
        next_tok[slot] = t0
    slot_of = {0: 0, 1: 2}                       # request idx -> slot
    for step in range(N_NEW + 2):
        if step == 2:
            t0, cache = handoffs[2]
            bc = cache_join(bc, cache, 1)        # late join into a free slot
            toks[1] = [t0]
            next_tok[1] = t0
            slot_of[2] = 1
        active = [s for s in toks if len(toks[s]) < N_NEW]
        if not active:
            break
        lg, bc = decode_step(cfg, params,
                             jnp.asarray([[t] for t in next_tok], jnp.int32),
                             bc)
        nxt = jnp.argmax(lg, axis=-1)
        for s in active:                         # leave-on-finish: inactive
            t = int(nxt[s])                      # slots just step on garbage
            toks[s].append(t)
            next_tok[s] = t
    batched = [toks[slot_of[i]] for i in range(3)]
    assert batched == serial


def test_cache_take_roundtrip_continues_serial(tiny_dense):
    """cache_take (watchdog migration) must extract a slot that continues
    generating exactly like the never-batched serial cache."""
    cfg, params = tiny_dense
    rng = random.Random(1)
    ids = [rng.randrange(cfg.vocab_size) for _ in range(29)]
    t0, cache = _chunked_prefill(cfg, params, ids)
    serial, _ = _serial_decode(cfg, params, t0, cache, 6)

    bc = init_cache(cfg, 3, MAX_LEN)
    bc = cache_join(bc, cache, 1)
    toks = [t0]
    next_tok = [0, t0, 0]
    for _ in range(2):                           # two batched steps...
        lg, bc = decode_step(cfg, params,
                             jnp.asarray([[t] for t in next_tok], jnp.int32),
                             bc)
        t = int(jnp.argmax(lg[1]))
        toks.append(t)
        next_tok[1] = t
    taken = cache_take(bc, 1)                    # ...then migrate out
    rest, _ = _serial_decode(cfg, params, toks[-1], taken, 4)
    assert toks + rest[1:] == serial


# ---------------------------------------------------------------------------
# Paged (block-table) continuous decode == seed serial decode
# ---------------------------------------------------------------------------

NBT = MAX_LEN // BLOCK


@pytest.mark.paged
def test_paged_batched_continuous_decode_matches_serial(tiny_dense):
    """Requests joining a PAGED batch cache at different steps must
    generate exactly the tokens of the seed per-request serial decode —
    the paged mirror of the padded test above."""
    cfg, params = tiny_dense
    rng = random.Random(0)
    prompts = [[rng.randrange(cfg.vocab_size) for _ in range(L)]
               for L in (23, 37, 11)]
    serial, handoffs = [], []
    for ids in prompts:
        t0, cache = _chunked_prefill(cfg, params, ids)
        serial.append(_serial_decode(cfg, params, t0, cache, N_NEW)[0])
        handoffs.append((t0, cache))

    pool = BlockPool(16, BLOCK)
    pc = init_paged_cache(cfg, 4, 16, MAX_LEN, BLOCK)
    toks = {}
    next_tok = [0] * 4
    slot_of = {}

    def join(ridx, slot):
        nonlocal pc
        t0, cache = handoffs[ridx]
        ids = pool.alloc(pool.blocks_for(len(prompts[ridx]) + N_NEW - 1))
        tab = jnp.asarray(pad_block_table(ids, NBT), jnp.int32)
        pc = paged_cache_join(cfg, pc, cache, slot, tab)
        toks[slot] = [t0]
        next_tok[slot] = t0
        slot_of[ridx] = slot

    join(0, 0)
    join(1, 2)
    for step in range(N_NEW + 2):
        if step == 2:
            join(2, 1)                           # late join into a free slot
        active = [s for s in toks if len(toks[s]) < N_NEW]
        if not active:
            break
        lg, pc = paged_decode_step(
            cfg, params, jnp.asarray([[t] for t in next_tok], jnp.int32), pc)
        nxt = jnp.argmax(lg, axis=-1)
        for s in active:
            t = int(nxt[s])
            toks[s].append(t)
            next_tok[s] = t
    batched = [toks[slot_of[i]] for i in range(3)]
    assert batched == serial


@pytest.mark.paged
def test_paged_take_roundtrip_continues_serial(tiny_dense):
    """paged_cache_take (watchdog migration) must extract a dense batch-1
    cache that continues generating exactly like the never-paged serial
    cache, and the freed pages must return to the pool."""
    cfg, params = tiny_dense
    rng = random.Random(1)
    ids = [rng.randrange(cfg.vocab_size) for _ in range(29)]
    t0, cache = _chunked_prefill(cfg, params, ids)
    serial, _ = _serial_decode(cfg, params, t0, cache, 6)

    pool = BlockPool(12, BLOCK)
    pc = init_paged_cache(cfg, 3, 12, MAX_LEN, BLOCK)
    blocks = pool.alloc(pool.blocks_for(29 + 6))
    pc = paged_cache_join(
        cfg, pc, cache, 1,
        jnp.asarray(pad_block_table(blocks, NBT), jnp.int32))
    toks = [t0]
    next_tok = [0, t0, 0]
    for _ in range(2):                           # two paged steps...
        lg, pc = paged_decode_step(
            cfg, params, jnp.asarray([[t] for t in next_tok], jnp.int32), pc)
        t = int(jnp.argmax(lg[1]))
        toks.append(t)
        next_tok[1] = t
    taken = paged_cache_take(cfg, pc, 1)         # ...then migrate out
    pc = paged_cache_clear_slot(pc, 1)
    pool.free(blocks)
    pool.check()
    rest, _ = _serial_decode(cfg, params, toks[-1], taken, 4)
    assert toks + rest[1:] == serial


# ---------------------------------------------------------------------------
# Real P/D handoff through ClusterRuntime
# ---------------------------------------------------------------------------

def _mk_requests(cfg, n=4, out_len=3, seed=0):
    rng = random.Random(seed)
    reqs = []
    for i in range(n):
        L = rng.randrange(16, 48)
        reqs.append(Request(
            rid=i, arrival_time=i * 0.02, input_len=L, output_len=out_len,
            tokens=tuple(rng.randrange(cfg.vocab_size) for _ in range(L))))
    return reqs


@pytest.fixture(scope="module")
def shared_spec(tiny_dense):
    cfg, params = tiny_dense
    return EngineSpec(cfg, params, max_len=MAX_LEN, max_batch=8, max_new=3)


@pytest.mark.parametrize("scheduler", ["sbs", "sbs-la"])
def test_real_pd_handoff_conserves_and_completes(tiny_dense, shared_spec,
                                                 scheduler):
    cfg, params = tiny_dense
    reqs = _mk_requests(cfg)
    arrivals = [r.arrival_time for r in reqs]
    srv = RealSBSServer(cfg, params, scheduler=scheduler, max_len=MAX_LEN,
                        max_new=3, spec=shared_spec)
    assert isinstance(srv.runtime, ClusterRuntime)   # one driver, both planes
    gens = srv.serve(reqs, timeout=120)

    # completion: every request finishes exactly once with its full output
    assert sorted(g.rid for g in gens) == [r.rid for r in reqs]
    for g, r in zip(gens, reqs):
        assert len(g.tokens) == r.output_len
        assert r.generated == r.output_len
    # timestamps: dispatch -> first chunk start -> first token -> finish,
    # with prefill_start stamped at chunk START (satellite regression)
    for r in reqs:
        assert r.prefill_start is not None
        assert r.dispatch_time <= r.prefill_start <= r.first_token_time
        assert r.arrival_time <= r.first_token_time <= r.finish_time
    # caller-owned arrival times are never rewritten (satellite regression)
    assert [r.arrival_time for r in reqs] == arrivals
    # conservation: decode accounting fully drained, tokens additive
    assert sum(d.kv_tokens for d in srv.state.decode_dps) == 0
    assert sum(d.batch for d in srv.state.decode_dps) == 0
    decoded = sum(e.tokens_generated for e in srv.decode_engines)
    # the first token of each request is emitted by the prefill plane
    assert decoded == sum(r.output_len - 1 for r in reqs)
    prefilled = sum(e.tokens_processed for e in srv.engines)
    assert prefilled == sum(r.input_len for r in reqs)


def test_worker_error_surfaces_promptly(tiny_dense):
    """A failing forward on an engine worker thread must raise out of
    serve() immediately, not leave the runtime blocked until the
    timeout horizon."""
    cfg, params = tiny_dense
    spec = EngineSpec(cfg, params, max_len=MAX_LEN, max_batch=4, max_new=3)

    def boom(p, t, c):
        raise RuntimeError("boom")

    spec.jit_prefill_chunk = boom
    srv = RealSBSServer(cfg, params, scheduler="sbs", max_len=MAX_LEN,
                        max_new=3, spec=spec)
    t0 = time.monotonic()
    with pytest.raises(RuntimeError, match="boom"):
        srv.serve(_mk_requests(cfg, n=2), timeout=60)
    assert time.monotonic() - t0 < 30


def test_real_ttft_stamped_at_prefill_completion(tiny_dense, shared_spec):
    """On the real plane the first token is produced by the prefill
    engine: its stamp must survive the handoff (TTFT is NOT deferred to
    the first batched decode step, which emits token #2)."""
    cfg, params = tiny_dense
    reqs = _mk_requests(cfg, n=3, seed=3)
    srv = RealSBSServer(cfg, params, scheduler="sbs", max_len=MAX_LEN,
                        max_new=3, spec=shared_spec)
    step_times = []
    for eng in srv.decode_engines:          # record decode step completions
        orig = eng.finish_step
        eng.finish_step = (lambda now, dps, _o=orig:
                           (step_times.append(now), _o(now, dps))[1])
    gens = srv.serve(reqs, timeout=120)
    assert len(gens) == 3
    for r in reqs:
        # the stamp is a prefill pass_end, never a decode step_end (the
        # old behavior re-stamped TTFT at a decode step completion)
        assert r.first_token_time not in step_times
        assert r.first_token_time < r.finish_time
        # and it precedes every decode step this request participated in
        assert any(r.first_token_time < t for t in step_times)


def test_real_immediate_baseline_completes(tiny_dense, shared_spec):
    """The immediate baseline runs over the same plane unchanged."""
    cfg, params = tiny_dense
    reqs = _mk_requests(cfg, n=3, seed=2)
    srv = RealSBSServer(cfg, params, scheduler="immediate", max_len=MAX_LEN,
                        max_new=3, spec=shared_spec)
    gens = srv.serve(reqs, timeout=120)
    assert len(gens) == 3
    assert all(len(g.tokens) == 3 for g in gens)


def test_repeated_serve_completes_without_timeline_stall(tiny_dense,
                                                         shared_spec):
    """serve() may be called repeatedly on one server: the runtime resets
    time-gated scheduler stamps (reset_clock) so a second run is not
    stalled by the previous run's timeline.  The adaptive T_fwd estimate
    deliberately persists (warm start), so run 2 is only required to be
    correct and no slower than run 1 — not instant."""
    cfg, params = tiny_dense
    srv = RealSBSServer(cfg, params, scheduler="sbs", max_len=MAX_LEN,
                        max_new=3, spec=shared_spec)
    t0 = time.monotonic()
    g1 = srv.serve(_mk_requests(cfg, seed=4), timeout=120)
    d1 = time.monotonic() - t0
    t0 = time.monotonic()
    g2 = srv.serve(_mk_requests(cfg, seed=4), timeout=120)
    d2 = time.monotonic() - t0
    assert len(g1) == len(g2) == 4
    assert [g.tokens for g in g1] == [g.tokens for g in g2]
    # the regression guarded here is a STALL (run 2 sleeping out the old
    # timeline — tens of seconds); allow generous wall-clock noise, this
    # is not a perf assertion
    assert d2 <= d1 * 2 + 2.0


# ---------------------------------------------------------------------------
# Paged admission: free blocks, not free slots
# ---------------------------------------------------------------------------

def _publish_handoffs(cfg, params, bus, reqs):
    """Stage every request on the handoff bus the way the prefill plane
    would (batch-1 cache + first token), marking generated=1."""
    cache_by_len = {}
    for r in reqs:
        if r.input_len not in cache_by_len:
            cache_by_len[r.input_len] = _chunked_prefill(
                cfg, params, list(r.tokens))
        t0, cache = cache_by_len[r.input_len]
        bus.publish(r.rid, cache, t0)
        r.generated = 1


@pytest.mark.paged
def test_paged_admission_by_free_blocks_not_slots(tiny_dense):
    """At EQUAL KV memory (max_batch × max_len tokens per DP) the paged
    engine must admit strictly more concurrent short requests than the
    padded engine, whose limit is its slot count."""
    cfg, params = tiny_dense
    rng = random.Random(5)
    reqs = [Request(rid=i, arrival_time=0.0, input_len=20, output_len=3,
                    tokens=tuple(rng.randrange(cfg.vocab_size)
                                 for _ in range(20)))
            for i in range(6)]

    def resident_after_joins(spec):
        bus = KVHandoffBus()
        _publish_handoffs(cfg, params, bus, reqs)
        eng = RealDecodeEngine(0, [0], spec, bus)
        st = DecodeDPState(dp_id=0, instance_id=0,
                           block_size=spec.block_size)
        free0 = eng.free_kv_tokens(0)
        assert free0 == 2 * MAX_LEN       # equal budget on both backends
        for r in reqs:
            r.generated = 1
            eng.admit(0, r)
        eng._apply_joins(0.0, [st])
        # the headroom probe tracks what admission consumed: slots×max_len
        # (padded) or reserved pages×block_size (paged)
        consumed = (len(eng._slot_of) * MAX_LEN if not spec.block_size
                    else sum(len(s.held[r.rid]) for s in eng._dp.values()
                             for r in reqs if r.rid in s.held) * BLOCK)
        assert eng.free_kv_tokens(0) == free0 - consumed
        return len(eng._slot_of)

    padded = EngineSpec(cfg, params, max_len=MAX_LEN, max_batch=2,
                        max_new=3)
    paged = EngineSpec(cfg, params, max_len=MAX_LEN, max_batch=2,
                       max_new=3, block_size=BLOCK, decode_slots=8)
    # same memory budget: pool holds exactly the padded plane's tokens
    assert (paged.paged_pool_blocks - 1) * BLOCK == 2 * MAX_LEN
    n_padded = resident_after_joins(padded)
    n_paged = resident_after_joins(paged)
    assert n_padded == 2                      # slot-bound
    # block-bound: ceil((20+3-1)/16) = 2 blocks per request, 12 usable
    assert n_paged == 6
    assert n_paged > n_padded


@pytest.mark.paged
def test_paged_join_defers_when_pool_exhausted(tiny_dense):
    """Over-admitted requests wait on the pending list (retried after
    each step) instead of corrupting live pages."""
    cfg, params = tiny_dense
    rng = random.Random(6)
    reqs = [Request(rid=i, arrival_time=0.0, input_len=40, output_len=3,
                    tokens=tuple(rng.randrange(cfg.vocab_size)
                                 for _ in range(40)))
            for i in range(4)]
    spec = EngineSpec(cfg, params, max_len=MAX_LEN, max_batch=1,
                      max_new=3, block_size=BLOCK, decode_slots=8)
    bus = KVHandoffBus()
    _publish_handoffs(cfg, params, bus, reqs)
    eng = RealDecodeEngine(0, [0], spec, bus)
    st = DecodeDPState(dp_id=0, instance_id=0, block_size=BLOCK)
    for r in reqs:
        eng.admit(0, r)
    eng._apply_joins(0.0, [st])
    # pool = 1*96/16 = 6 usable blocks; each request needs ceil(42/16)=3
    assert len(eng._slot_of) == 2
    assert len(eng._pending) == 2             # deferred, not dropped
    assert eng.has_work()


@pytest.mark.paged
def test_paged_drain_migrates_and_frees_pages(tiny_dense):
    """Watchdog drain on a paged engine re-parks residents as DENSE
    batch-1 caches on the bus (the cross-plane handoff format), clears
    their table rows, and returns every page to the pool — a drained
    request can re-join (padded or paged) with generation state intact."""
    cfg, params = tiny_dense
    rng = random.Random(8)
    reqs = [Request(rid=i, arrival_time=0.0, input_len=25, output_len=4,
                    tokens=tuple(rng.randrange(cfg.vocab_size)
                                 for _ in range(25)))
            for i in range(2)]
    spec = EngineSpec(cfg, params, max_len=MAX_LEN, max_batch=2,
                      max_new=4, block_size=BLOCK)
    bus = KVHandoffBus()
    _publish_handoffs(cfg, params, bus, reqs)
    eng = RealDecodeEngine(0, [0], spec, bus)
    st = DecodeDPState(dp_id=0, instance_id=0, block_size=BLOCK)
    for r in reqs:
        eng.admit(0, r)
    eng._apply_joins(0.0, [st])
    assert len(eng._slot_of) == 2
    pool = eng._dp[0].pool
    assert pool.used_count > 0
    out = eng.drain()
    assert sorted(r.rid for rs in out.values() for r in rs) == [0, 1]
    pool.check()
    assert pool.used_count == 0                # every page came back
    assert not eng._slot_of and not eng._dp[0].occupied()
    for r in reqs:
        gen = bus.gen(r.rid)
        assert gen.cache is not None           # re-parked, dense format
        assert gen.cache["kv_pos"].shape == (1, MAX_LEN)
        assert int(gen.cache["cur"][0]) == r.input_len


# ---------------------------------------------------------------------------
# Worker-error surfacing (RealtimeEventLoop regression)
# ---------------------------------------------------------------------------

def test_decode_worker_error_surfaces_within_window(tiny_dense):
    """A failing DECODE forward on the engine worker thread must raise
    out of serve() within one scheduling window of the failure — the
    loop may not sleep out the remaining timeout horizon.  (The prefill
    twin lives in test_worker_error_surfaces_promptly; this one covers
    the step_end path, which reaches the runtime via a different
    completion event.)"""
    cfg, params = tiny_dense
    spec = EngineSpec(cfg, params, max_len=MAX_LEN, max_batch=4, max_new=3)

    def boom(p, t, c):
        raise RuntimeError("decode boom")

    spec.jit_decode = boom
    srv = RealSBSServer(cfg, params, scheduler="sbs", max_len=MAX_LEN,
                        max_new=3, spec=spec)
    t0 = time.monotonic()
    with pytest.raises(RuntimeError, match="decode boom"):
        srv.serve(_mk_requests(cfg, n=2), timeout=120)
    elapsed = time.monotonic() - t0
    # prefill (healthy) + one failed step, orders of magnitude below the
    # 120s horizon the old busy-wait would have slept out
    assert elapsed < 30


@pytest.mark.paged
def test_paged_decode_worker_error_surfaces_within_window(tiny_dense):
    """Same regression over the paged step path."""
    cfg, params = tiny_dense
    spec = EngineSpec(cfg, params, max_len=MAX_LEN, max_batch=4, max_new=3,
                      block_size=BLOCK)

    def boom(p, t, c):
        raise RuntimeError("paged boom")

    spec.jit_paged_decode = boom
    scfg = ServingConfig(num_prefill_instances=2, prefill_dp_per_instance=2,
                         num_decode_instances=1, decode_dp_per_instance=2,
                         chunk_size=32, t_default=0.05, l_net=0.001,
                         max_batch_per_dp=4, block_size=BLOCK)
    srv = RealSBSServer(cfg, params, serving_cfg=scfg, scheduler="sbs",
                        max_len=MAX_LEN, max_new=3, spec=spec)
    t0 = time.monotonic()
    with pytest.raises(RuntimeError, match="paged boom"):
        srv.serve(_mk_requests(cfg, n=2), timeout=120)
    assert time.monotonic() - t0 < 30


# ---------------------------------------------------------------------------
# Cross-plane equivalence sweep (sim/real × padded/paged)
# ---------------------------------------------------------------------------

def _oracle_tokens(cfg, params, req, cache_ref):
    """Seed-server reference generation for one request (memoized)."""
    if req.rid not in cache_ref:
        t0, cache = _chunked_prefill(cfg, params, list(req.tokens))
        cache_ref[req.rid] = _serial_decode(cfg, params, t0, cache,
                                            req.output_len)[0]
    return cache_ref[req.rid]


@pytest.mark.paged
@pytest.mark.slow
@pytest.mark.parametrize("plane", ["sim-padded", "sim-paged",
                                   "real-padded", "real-paged"])
def test_cross_plane_equivalence(tiny_dense, plane):
    """One workload, four deployments.  Conservation must hold on every
    plane (requests in == completions; no KV tokens or blocks outlive
    their request) and the real planes must be token-exact against the
    seed serial decode — which also makes real-padded and real-paged
    token-exact against each other."""
    from repro.serving.e2e import PDClusterSim

    cfg, params = tiny_dense
    kind, backend = plane.split("-")
    scfg = ServingConfig(num_prefill_instances=2, prefill_dp_per_instance=2,
                         num_decode_instances=1, decode_dp_per_instance=2,
                         chunk_size=32, t_default=0.05, l_net=0.001,
                         max_batch_per_dp=4,
                         block_size=BLOCK if backend == "paged" else 0)
    reqs = _mk_requests(cfg, n=5, out_len=3, seed=11)

    if kind == "sim":
        sim = PDClusterSim(cfg, scfg, scheduler="sbs")
        sim.run(reqs, duration=2.0)
        state = sim.state
        engines = sim.decode
    else:
        srv = RealSBSServer(cfg, params, serving_cfg=scfg, scheduler="sbs",
                            max_len=MAX_LEN, max_new=3)
        gens = srv.serve(reqs, timeout=120)
        state = srv.state
        engines = srv.decode_engines
        # token-exact vs the seed serial decode
        oracle_cache = {}
        assert sorted(g.rid for g in gens) == [r.rid for r in reqs]
        for g, r in zip(gens, reqs):
            assert g.tokens == _oracle_tokens(cfg, params, r, oracle_cache)
        # device-side pools fully drained
        for e in engines:
            for st in e._dp.values():
                if scfg.block_size:
                    st.pool.check()
                    assert st.pool.used_count == 0
                assert not st.occupied()

    # requests in == completions (every request finished exactly once)
    assert all(r.finish_time is not None for r in reqs)
    assert all(r.generated == r.output_len for r in reqs)
    # no KV tokens (or reserved blocks) outlive their request
    assert sum(d.kv_tokens for d in state.decode_dps) == 0
    assert sum(d.batch for d in state.decode_dps) == 0
    assert sum(d.kv_blocks for d in state.decode_dps) == 0
    # decode plane emitted exactly the non-prefill tokens
    decoded = sum(e.tokens_generated for e in engines)
    first_from_prefill = 1 if kind == "real" else 0
    assert decoded == sum(r.output_len - first_from_prefill for r in reqs)
