"""The real engine plane behind the unified ClusterRuntime:

  * token-level equivalence of continuous batched decode (padded batch
    cache + join/leave) against the seed per-request serial decode
  * cache_take/cache_join round trip (the watchdog migration path)
  * conservation + completion invariants of the real P/D handoff under
    `sbs` and `sbs-la`, including the satellite regressions:
      - prefill_start stamped when the first chunk STARTS (not at
        prefill completion)
      - serve() leaves caller-owned Request.arrival_time untouched
"""
import random
import time

import jax
import jax.numpy as jnp
import pytest

from repro.config import ServingConfig, get_arch
from repro.core.types import Request
from repro.models import (
    cache_join, cache_take, decode_step, init_cache, init_params,
    prefill_chunk,
)
from repro.serving.real_engine import EngineSpec
from repro.serving.runtime import ClusterRuntime
from repro.serving.server import RealSBSServer

MAX_LEN = 96
N_NEW = 5


@pytest.fixture(scope="module")
def tiny_dense():
    cfg = get_arch("deepseek-7b", reduced=True)   # dense: exact equivalence
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _chunked_prefill(cfg, params, ids, chunk=16):
    """The seed server's prefill algorithm: batch-1 chunked KV build."""
    cache = init_cache(cfg, 1, MAX_LEN)
    logits = None
    for i in range(0, len(ids), chunk):
        arr = jnp.asarray([ids[i:i + chunk]], jnp.int32)
        logits, cache = prefill_chunk(cfg, params, arr, cache)
    return int(jnp.argmax(logits[0])), cache


def _serial_decode(cfg, params, t0, cache, n):
    """The seed server's decode loop: batch-of-1, token by token."""
    toks = [t0]
    for _ in range(n - 1):
        lg, cache = decode_step(cfg, params,
                                jnp.asarray([[toks[-1]]], jnp.int32), cache)
        toks.append(int(jnp.argmax(lg[0])))
    return toks, cache


# ---------------------------------------------------------------------------
# Batched continuous decode == seed serial decode
# ---------------------------------------------------------------------------

def test_batched_continuous_decode_matches_serial(tiny_dense):
    """Requests joining a padded batch cache at different steps (continuous
    batching) must generate exactly the tokens of the seed per-request
    serial decode."""
    cfg, params = tiny_dense
    rng = random.Random(0)
    prompts = [[rng.randrange(cfg.vocab_size) for _ in range(L)]
               for L in (23, 37, 11)]
    serial, handoffs = [], []
    for ids in prompts:
        t0, cache = _chunked_prefill(cfg, params, ids)
        serial.append(_serial_decode(cfg, params, t0, cache, N_NEW)[0])
        handoffs.append((t0, cache))

    B = 4
    bc = init_cache(cfg, B, MAX_LEN)
    # join-on-handoff: r0/r1 up front, r2 joins two steps later
    toks = {}
    next_tok = [0] * B
    for slot, ridx in ((0, 0), (2, 1)):
        t0, cache = handoffs[ridx]
        bc = cache_join(bc, cache, slot)
        toks[slot] = [t0]
        next_tok[slot] = t0
    slot_of = {0: 0, 1: 2}                       # request idx -> slot
    for step in range(N_NEW + 2):
        if step == 2:
            t0, cache = handoffs[2]
            bc = cache_join(bc, cache, 1)        # late join into a free slot
            toks[1] = [t0]
            next_tok[1] = t0
            slot_of[2] = 1
        active = [s for s in toks if len(toks[s]) < N_NEW]
        if not active:
            break
        lg, bc = decode_step(cfg, params,
                             jnp.asarray([[t] for t in next_tok], jnp.int32),
                             bc)
        nxt = jnp.argmax(lg, axis=-1)
        for s in active:                         # leave-on-finish: inactive
            t = int(nxt[s])                      # slots just step on garbage
            toks[s].append(t)
            next_tok[s] = t
    batched = [toks[slot_of[i]] for i in range(3)]
    assert batched == serial


def test_cache_take_roundtrip_continues_serial(tiny_dense):
    """cache_take (watchdog migration) must extract a slot that continues
    generating exactly like the never-batched serial cache."""
    cfg, params = tiny_dense
    rng = random.Random(1)
    ids = [rng.randrange(cfg.vocab_size) for _ in range(29)]
    t0, cache = _chunked_prefill(cfg, params, ids)
    serial, _ = _serial_decode(cfg, params, t0, cache, 6)

    bc = init_cache(cfg, 3, MAX_LEN)
    bc = cache_join(bc, cache, 1)
    toks = [t0]
    next_tok = [0, t0, 0]
    for _ in range(2):                           # two batched steps...
        lg, bc = decode_step(cfg, params,
                             jnp.asarray([[t] for t in next_tok], jnp.int32),
                             bc)
        t = int(jnp.argmax(lg[1]))
        toks.append(t)
        next_tok[1] = t
    taken = cache_take(bc, 1)                    # ...then migrate out
    rest, _ = _serial_decode(cfg, params, toks[-1], taken, 4)
    assert toks + rest[1:] == serial


# ---------------------------------------------------------------------------
# Real P/D handoff through ClusterRuntime
# ---------------------------------------------------------------------------

def _mk_requests(cfg, n=4, out_len=3, seed=0):
    rng = random.Random(seed)
    reqs = []
    for i in range(n):
        L = rng.randrange(16, 48)
        reqs.append(Request(
            rid=i, arrival_time=i * 0.02, input_len=L, output_len=out_len,
            tokens=tuple(rng.randrange(cfg.vocab_size) for _ in range(L))))
    return reqs


@pytest.fixture(scope="module")
def shared_spec(tiny_dense):
    cfg, params = tiny_dense
    return EngineSpec(cfg, params, max_len=MAX_LEN, max_batch=8, max_new=3)


@pytest.mark.parametrize("scheduler", ["sbs", "sbs-la"])
def test_real_pd_handoff_conserves_and_completes(tiny_dense, shared_spec,
                                                 scheduler):
    cfg, params = tiny_dense
    reqs = _mk_requests(cfg)
    arrivals = [r.arrival_time for r in reqs]
    srv = RealSBSServer(cfg, params, scheduler=scheduler, max_len=MAX_LEN,
                        max_new=3, spec=shared_spec)
    assert isinstance(srv.runtime, ClusterRuntime)   # one driver, both planes
    gens = srv.serve(reqs, timeout=120)

    # completion: every request finishes exactly once with its full output
    assert sorted(g.rid for g in gens) == [r.rid for r in reqs]
    for g, r in zip(gens, reqs):
        assert len(g.tokens) == r.output_len
        assert r.generated == r.output_len
    # timestamps: dispatch -> first chunk start -> first token -> finish,
    # with prefill_start stamped at chunk START (satellite regression)
    for r in reqs:
        assert r.prefill_start is not None
        assert r.dispatch_time <= r.prefill_start <= r.first_token_time
        assert r.arrival_time <= r.first_token_time <= r.finish_time
    # caller-owned arrival times are never rewritten (satellite regression)
    assert [r.arrival_time for r in reqs] == arrivals
    # conservation: decode accounting fully drained, tokens additive
    assert sum(d.kv_tokens for d in srv.state.decode_dps) == 0
    assert sum(d.batch for d in srv.state.decode_dps) == 0
    decoded = sum(e.tokens_generated for e in srv.decode_engines)
    # the first token of each request is emitted by the prefill plane
    assert decoded == sum(r.output_len - 1 for r in reqs)
    prefilled = sum(e.tokens_processed for e in srv.engines)
    assert prefilled == sum(r.input_len for r in reqs)


def test_worker_error_surfaces_promptly(tiny_dense):
    """A failing forward on an engine worker thread must raise out of
    serve() immediately, not leave the runtime blocked until the
    timeout horizon."""
    cfg, params = tiny_dense
    spec = EngineSpec(cfg, params, max_len=MAX_LEN, max_batch=4, max_new=3)

    def boom(p, t, c):
        raise RuntimeError("boom")

    spec.jit_prefill_chunk = boom
    srv = RealSBSServer(cfg, params, scheduler="sbs", max_len=MAX_LEN,
                        max_new=3, spec=spec)
    t0 = time.monotonic()
    with pytest.raises(RuntimeError, match="boom"):
        srv.serve(_mk_requests(cfg, n=2), timeout=60)
    assert time.monotonic() - t0 < 30


def test_real_ttft_stamped_at_prefill_completion(tiny_dense, shared_spec):
    """On the real plane the first token is produced by the prefill
    engine: its stamp must survive the handoff (TTFT is NOT deferred to
    the first batched decode step, which emits token #2)."""
    cfg, params = tiny_dense
    reqs = _mk_requests(cfg, n=3, seed=3)
    srv = RealSBSServer(cfg, params, scheduler="sbs", max_len=MAX_LEN,
                        max_new=3, spec=shared_spec)
    step_times = []
    for eng in srv.decode_engines:          # record decode step completions
        orig = eng.finish_step
        eng.finish_step = (lambda now, dps, _o=orig:
                           (step_times.append(now), _o(now, dps))[1])
    gens = srv.serve(reqs, timeout=120)
    assert len(gens) == 3
    for r in reqs:
        # the stamp is a prefill pass_end, never a decode step_end (the
        # old behavior re-stamped TTFT at a decode step completion)
        assert r.first_token_time not in step_times
        assert r.first_token_time < r.finish_time
        # and it precedes every decode step this request participated in
        assert any(r.first_token_time < t for t in step_times)


def test_real_immediate_baseline_completes(tiny_dense, shared_spec):
    """The immediate baseline runs over the same plane unchanged."""
    cfg, params = tiny_dense
    reqs = _mk_requests(cfg, n=3, seed=2)
    srv = RealSBSServer(cfg, params, scheduler="immediate", max_len=MAX_LEN,
                        max_new=3, spec=shared_spec)
    gens = srv.serve(reqs, timeout=120)
    assert len(gens) == 3
    assert all(len(g.tokens) == 3 for g in gens)


def test_repeated_serve_completes_without_timeline_stall(tiny_dense,
                                                         shared_spec):
    """serve() may be called repeatedly on one server: the runtime resets
    time-gated scheduler stamps (reset_clock) so a second run is not
    stalled by the previous run's timeline.  The adaptive T_fwd estimate
    deliberately persists (warm start), so run 2 is only required to be
    correct and no slower than run 1 — not instant."""
    cfg, params = tiny_dense
    srv = RealSBSServer(cfg, params, scheduler="sbs", max_len=MAX_LEN,
                        max_new=3, spec=shared_spec)
    t0 = time.monotonic()
    g1 = srv.serve(_mk_requests(cfg, seed=4), timeout=120)
    d1 = time.monotonic() - t0
    t0 = time.monotonic()
    g2 = srv.serve(_mk_requests(cfg, seed=4), timeout=120)
    d2 = time.monotonic() - t0
    assert len(g1) == len(g2) == 4
    assert [g.tokens for g in g1] == [g.tokens for g in g2]
    assert d2 <= d1 + 1.0
