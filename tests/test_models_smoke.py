"""Per-architecture smoke tests (task requirement): instantiate the REDUCED
variant of each family, run one forward/train step on CPU, assert output
shapes + no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.config import get_arch, list_archs
from repro.models import forward_train, init_params
from repro.models.model import forward_full, logits_from_hidden

ARCHS = list_archs()


def _batch(cfg, B=2, S=16, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 2)
    tokens = jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)
    targets = jnp.roll(tokens, -1, 1).at[:, -1].set(-100)
    batch = {"tokens": tokens, "targets": targets}
    if cfg.is_encoder_decoder:
        batch["embeds"] = jax.random.normal(
            ks[1], (B, cfg.encoder_seq_len, cfg.d_model)) * 0.1
    elif cfg.num_patch_tokens:
        batch["embeds"] = jax.random.normal(
            ks[1], (B, cfg.num_patch_tokens, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_smoke_forward_and_train_step(arch):
    cfg = get_arch(arch, reduced=True)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    assert cfg.moe.num_experts <= 4
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    # forward: logits shape + finite
    x, _, aux, _ = forward_full(cfg, params, batch["tokens"],
                                embeds=batch.get("embeds"))
    logits = logits_from_hidden(cfg, params, x)
    B, S = batch["tokens"].shape
    npre = 0 if cfg.is_encoder_decoder else cfg.num_patch_tokens
    assert logits.shape == (B, S + npre, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    # one train step (loss + grads finite, params update)
    def loss_fn(p):
        l, _ = forward_train(cfg, p, batch)
        return l
    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert gnorm > 0 and jnp.isfinite(gnorm)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The full (never-instantiated) configs carry the exact assigned dims."""
    spec = {
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
        "minicpm3-4b": (62, 2560, 40, 40, 6400, 73448),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
        "deepseek-v3-671b": (61, 7168, 128, 128, 18432, 129280),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
    }[arch]
    cfg = get_arch(arch)
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == spec
    assert cfg.source
