"""Algorithm 1 — adaptive interval controller."""
import math

import pytest
from _hypothesis_shim import given, settings, st

from repro.core.interval import AdaptiveIntervalController


def test_formula():
    ic = AdaptiveIntervalController(window_size=8, l_net=0.01, t_default=0.2,
                                    n_active=4)
    assert ic.interval == pytest.approx((0.2 + 0.01) / 4)


def test_moving_average_window_eviction():
    ic = AdaptiveIntervalController(window_size=3, l_net=0.0, t_default=1.0,
                                    n_active=1)
    for t in [1.0, 2.0, 3.0]:
        ic.on_end_forward(t)
    assert ic.t_fwd == pytest.approx(2.0)
    ic.on_end_forward(10.0)          # evicts the 1.0 sample
    assert ic.t_fwd == pytest.approx((2 + 3 + 10) / 3)
    assert ic.interval == pytest.approx(ic.t_fwd / 1)


def test_topology_change_immediate():
    ic = AdaptiveIntervalController(t_default=0.4, l_net=0.0, n_active=2)
    i0 = ic.interval
    ic.on_topology_change(8)
    assert ic.interval == pytest.approx(i0 / 4)
    ic.on_topology_change(0)
    assert ic.interval == float("inf")   # no capacity: hold


def test_watchdog_is_5x():
    ic = AdaptiveIntervalController(t_default=0.3, n_active=1)
    assert ic.watchdog_timeout == pytest.approx(1.5)


def test_rejects_bad_inputs():
    with pytest.raises(ValueError):
        AdaptiveIntervalController(window_size=0)
    ic = AdaptiveIntervalController()
    with pytest.raises(ValueError):
        ic.on_end_forward(-1.0)


@given(ts=st.lists(st.floats(1e-4, 10.0), min_size=1, max_size=100),
       n=st.integers(1, 64), lnet=st.floats(0.0, 0.1))
@settings(max_examples=60, deadline=None)
def test_interval_always_matches_mean_over_window(ts, n, lnet):
    w = 16
    ic = AdaptiveIntervalController(window_size=w, l_net=lnet, n_active=n)
    for t in ts:
        ic.on_end_forward(t)
    mean = sum(ts[-w:]) / len(ts[-w:])
    assert ic.interval == pytest.approx((mean + lnet) / n)
    # I_opt scales 1/N: doubling capacity halves the interval
    ic.on_topology_change(2 * n)
    assert ic.interval == pytest.approx((mean + lnet) / (2 * n))
