"""§4.1.2 — multi-tier state synchronization protocol."""
from repro.core.sync import Readiness, SyncProtocol


def test_quiescence_path():
    sp = SyncProtocol(2)
    assert sp.readiness(0, 0.0) == Readiness.READY_QUIESCENT


def test_endforward_fast_path():
    sp = SyncProtocol(1)
    sp.on_dispatch(0, 0.0, t_fwd_est=0.1)
    assert sp.readiness(0, 0.01) == Readiness.BUSY
    sp.on_end_forward(0, 0.09)
    assert sp.is_ready(0, 0.1)


def test_watchdog_forces_reset():
    sp = SyncProtocol(1, watchdog_multiplier=5.0)
    sp.on_dispatch(0, 0.0, t_fwd_est=0.1)
    assert sp.readiness(0, 0.49) == Readiness.BUSY
    # past 5×T̄ with no EndForward: liveness reset
    assert sp.readiness(0, 0.51) == Readiness.READY_WATCHDOG
    assert sp.task_depth(0) == 0


def test_degradation_and_recovery():
    sp = SyncProtocol(1, degrade_after_trips=2)
    for k in range(2):
        sp.on_dispatch(0, k * 10.0, t_fwd_est=0.1)
        assert sp.readiness(0, k * 10.0 + 1.0) == Readiness.READY_WATCHDOG
    assert sp.is_degraded(0)         # fixed-interval fallback mode
    sp.on_dispatch(0, 100.0, t_fwd_est=0.1)
    sp.on_end_forward(0, 100.05)     # healthy signal clears degradation
    assert not sp.is_degraded(0)


def test_task_depth_counts_outstanding_batches():
    sp = SyncProtocol(1)
    sp.on_dispatch(0, 0.0, 0.1)
    sp.on_dispatch(0, 0.01, 0.1)
    assert sp.task_depth(0) == 2
    sp.on_end_forward(0, 0.1)
    assert sp.task_depth(0) == 1
    assert sp.readiness(0, 0.1) == Readiness.BUSY   # still one in flight
    sp.on_end_forward(0, 0.2)
    assert sp.readiness(0, 0.2) == Readiness.READY_QUIESCENT


def test_next_watchdog_deadline():
    sp = SyncProtocol(2)
    assert sp.next_watchdog_deadline(0.0) is None
    sp.on_dispatch(0, 0.0, 0.1)
    sp.on_dispatch(1, 0.2, 0.1)
    assert sp.next_watchdog_deadline(0.0) == 0.5
