"""Roofline cost model + workload sanity."""
import pytest

from repro.config import get_arch
from repro.serving.costmodel import CostModel, HBM_BW, ICI_BW, PEAK_FLOPS
from repro.serving.workload import (
    LONG, SHORT, WorkloadSpec, empirical_mean_len, generate, sample_length,
)


def test_prefill_time_monotone_and_floor():
    cm = CostModel(get_arch("deepseek-v3-671b"))
    t1 = cm.prefill_dp_time(1024)
    t2 = cm.prefill_dp_time(3072)
    assert 0 < t1 < t2
    # §3.2 batch-insensitive latency: partial passes cost >= min_fill·chunk
    floor = cm.prefill_pass_time([100], chunk=3072)
    assert floor >= cm.prefill_dp_time(int(3072 * cm.min_fill))


def test_pass_time_is_straggler_bound():
    cm = CostModel(get_arch("deepseek-v3-671b"), min_fill=0.0)
    balanced = cm.prefill_pass_time([1000, 1000, 1000, 1000])
    skewed = cm.prefill_pass_time([4000, 0, 0, 0])
    assert skewed > balanced          # sync barrier: max over DP units


def test_decode_time_couples_B_and_K():
    cm = CostModel(get_arch("deepseek-v3-671b"))
    base = cm.decode_dp_time(batch=32, kv_tokens=50_000)
    more_kv = cm.decode_dp_time(batch=32, kv_tokens=150_000)
    more_b = cm.decode_dp_time(batch=64, kv_tokens=50_000)
    assert more_kv > base             # K_i term (HBM reads)
    assert more_b > base              # B_i term (all-to-all bytes)


def test_mla_kv_bytes_much_smaller_than_mha():
    mla = CostModel(get_arch("minicpm3-4b")).kv_bytes_per_token
    mha = CostModel(get_arch("deepseek-7b")).kv_bytes_per_token
    assert mla * 10 < mha


def test_ssm_has_no_per_token_kv():
    cm = CostModel(get_arch("mamba2-370m"))
    assert cm.kv_bytes_per_token == 0


def test_workload_means_match_paper():
    # paper §5.1: 0–3K mean ~1K; 3K–64K mean ~6.7K
    assert empirical_mean_len(SHORT) == pytest.approx(1000, rel=0.15)
    assert empirical_mean_len(LONG) == pytest.approx(6700, rel=0.25)


def test_workload_poisson_rate():
    reqs = generate(SHORT, qps=100, duration=30, seed=0)
    assert len(reqs) == pytest.approx(3000, rel=0.1)
    assert all(reqs[i].arrival_time < reqs[i + 1].arrival_time
               for i in range(len(reqs) - 1))


def test_shared_prefix_generation():
    reqs = generate(SHORT, qps=50, duration=5, seed=0, with_tokens=True,
                    shared_prefix_prob=1.0)
    pres = {r.tokens[:64] for r in reqs if len(r.tokens) >= 64}
    assert len(pres) <= 4             # drawn from 4 shared prefixes
