"""Refcounted page sharing + copy-on-write, from allocator invariants up
to the real serving plane (offline-safe via tests/_hypothesis_shim).

Property layers:
  1. Refcount safety — a block is never returned to circulation while
     any holder still references it, under random share/unshare
     schedules; the pool conserves blocks exactly throughout.
  2. COW divergence — requests forking off shared prefixes and then
     writing (copy-on-write discipline) always read back exactly their
     unshared-oracle token content: aliasing can never corrupt a peer.
  3. Binder lifecycle — claim takes references, LRU eviction only
     unpins the cache's own references, a full drain leaks nothing.
Real plane:
  4. An exact repeat of a published prompt is a FULL prefix hit: zero
     prefill chunks run, the stored first token replays, generation is
     token-identical.
  5. Shared-prefix traffic through the whole server (prefix_cache=True)
     stays token-exact against the seed serial-decode oracle while
     actually sharing pages (blocks_shared > 0, cow_copies > 0), and
     evicting the caches afterwards drains every pool to zero.
"""
import random

import pytest
from _hypothesis_shim import given, settings, st

from repro.serving.kv_pool import BlockPool, OutOfBlocks
from repro.serving.page_share import PagePrefixBinder

pytestmark = pytest.mark.paged


# ---------------------------------------------------------------------------
# 1. Refcount safety + conservation under random share/unshare
# ---------------------------------------------------------------------------

@given(
    num_blocks=st.integers(3, 40),
    ops=st.lists(st.integers(0, 2), min_size=1, max_size=80),
    seed=st.integers(0, 999),
)
@settings(max_examples=40, deadline=None)
def test_shared_block_never_freed_while_referenced(num_blocks, ops, seed):
    """Random alloc / incref / decref schedule.  After every operation
    the pool conserves blocks (used ⊎ free = all), `used_count` counts
    each referenced block once regardless of its refcount, and no block
    with a live reference can ever be handed out again."""
    pool = BlockPool(num_blocks, 8)
    rng = random.Random(seed)
    refs = {}                                   # block -> our holder count
    for op in ops:
        if op == 0 and pool.free_count:         # new allocation
            b = pool.alloc(1)[0]
            refs[b] = refs.get(b, 0) + 1
            assert refs[b] == 1, "allocated a block someone still holds"
        elif op == 1 and refs:                  # share: one more holder
            b = rng.choice(list(refs))
            pool.incref([b])
            refs[b] += 1
        elif op == 2 and refs:                  # unshare: drop one holder
            b = rng.choice(list(refs))
            pool.free([b])
            refs[b] -= 1
            if not refs[b]:
                del refs[b]
        pool.check()
        assert pool.used_count == len(refs)
        assert pool.free_count + pool.used_count == num_blocks - 1
        for b, n in refs.items():
            assert pool.refcount(b) == n
            assert pool.is_shared(b) == (n > 1)
    # nothing referenced may be in the free store: drain it and look
    probe = pool.alloc(pool.free_count)
    assert not set(probe) & set(refs)
    pool.free(probe)
    for b, n in refs.items():                   # release every holder
        pool.free([b] * n)
    pool.check()
    assert pool.free_count == num_blocks - 1


# ---------------------------------------------------------------------------
# 2. COW divergence == unshared oracle (virtual block contents)
# ---------------------------------------------------------------------------

BS = 4


@given(
    ops=st.lists(st.integers(0, 9), min_size=4, max_size=100),
    seed=st.integers(0, 999),
)
@settings(max_examples=40, deadline=None)
def test_cow_divergence_matches_unshared_oracle(ops, seed):
    """Requests fork off each other's tables (incref — the claim path)
    and keep writing under copy-on-write discipline: a write to a shared
    block first copies it.  Each request's readable token stream must
    stay exactly its private oracle's — sharing must be unobservable."""
    pool = BlockPool(48, BS)
    content = {}                    # block -> frozen-or-owned token list
    live = []                       # (table, oracle) pairs
    rng = random.Random(seed)

    def write(table, oracle, tok):
        bi = len(oracle) // BS
        if bi == len(table):                        # grow: fresh block
            b = pool.alloc(1)[0]
            content[b] = []
            table.append(b)
        b = table[bi]
        if pool.is_shared(b):                       # copy-on-write
            nb = pool.alloc(1)[0]
            content[nb] = list(content[b])
            pool.free([b])
            table[bi] = nb
            b = nb
        content[b].append(tok)
        oracle.append(tok)

    for op in ops:
        if op == 0 and len(live) < 6:               # new empty request
            live.append(([], []))
        elif op == 1 and live and len(live) < 6:    # fork a full table
            table, oracle = live[rng.randrange(len(live))]
            pool.incref(table)
            live.append((list(table), list(oracle)))
        elif live and pool.free_count >= 2:         # write a token
            table, oracle = live[rng.randrange(len(live))]
            if len(oracle) < len(table) * BS + BS:
                write(table, oracle, rng.randrange(1000))
        if op == 9 and live:                        # retire
            table, _ = live.pop(rng.randrange(len(live)))
            pool.free(table)
        pool.check()
        for table, oracle in live:
            got = [t for b in table for t in content[b]]
            assert got == oracle, "a peer's write leaked into this table"
    for table, _ in live:
        pool.free(table)
    pool.check()
    assert pool.free_count == pool.num_blocks - 1


# ---------------------------------------------------------------------------
# 3. Binder lifecycle: claim refs, eviction-as-decref, clean drain
# ---------------------------------------------------------------------------

def test_binder_claim_insert_evict_lifecycle():
    B = 16
    pool = BlockPool(16, B)
    binder = PagePrefixBinder(pool)
    rng = random.Random(3)
    prompt = [rng.randrange(500) for _ in range(2 * B + 5)]  # partial tail

    # publish a finished prompt: 3 pages (tail bound via first_token)
    tab = pool.alloc(3)
    binder.insert(prompt, tab, first_token=42)
    pool.free(tab)                      # engine lets go; the TREE holds on
    assert pool.used_count == 3

    # exact repeat => full hit incl. the tail page and the stored token
    claim, blocks, first = binder.claim(prompt)
    assert (claim, first) == (len(prompt), 42)
    assert blocks == tab and all(pool.is_shared(b) for b in blocks)

    # longer prompt sharing the prefix => full blocks only, no token
    claim2, blocks2, first2 = binder.claim(prompt + [7] * B)
    assert (claim2, first2) == (2 * B, None)
    assert blocks2 == tab[:2]

    # pool pressure: eviction decrefs the tree's references, but pages
    # the claims still hold survive in the used set
    assert binder.ensure_free(pool.num_blocks - 1) is False
    assert pool.used_count == 3 and pool.free_count == 12
    pool.free(blocks)                   # release the full-hit claim
    assert pool.used_count == 2         # tail page died with its last ref
    pool.free(blocks2)
    pool.check()
    assert pool.free_count == pool.num_blocks - 1

    # claiming from the emptied cache finds nothing
    assert binder.claim(prompt) == (0, [], None)


# ---------------------------------------------------------------------------
# 4/5. Real plane: full-hit skips prefill; e2e token-exactness while sharing
# ---------------------------------------------------------------------------

MAX_LEN, BLOCK = 96, 16


@pytest.fixture(scope="module")
def share_server():
    import jax
    from repro.config import ServingConfig, get_arch
    from repro.models import init_params
    from repro.serving.server import RealSBSServer

    cfg = get_arch("deepseek-7b", reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    # ONE prefill instance: SBS staggers dispatch windows per instance,
    # so with several instances a repeat prompt only probabilistically
    # lands on the engine holding its pages — a single instance (its two
    # DPs share the engine's binder) makes the full hit deterministic
    scfg = ServingConfig(num_prefill_instances=1, prefill_dp_per_instance=2,
                         num_decode_instances=1, decode_dp_per_instance=2,
                         chunk_size=32, t_default=0.05, l_net=0.001,
                         max_batch_per_dp=4, block_size=BLOCK)
    srv = RealSBSServer(cfg, params, serving_cfg=scfg, scheduler="sbs",
                        max_len=MAX_LEN, max_new=3, prefix_cache=True)
    return cfg, params, srv


def _req(rid, tokens, t=0.0, out=3):
    from repro.core.types import Request
    return Request(rid=rid, arrival_time=t, input_len=len(tokens),
                   output_len=out, tokens=tuple(tokens))


def test_full_prefix_hit_runs_zero_chunks(share_server):
    """Serving an exact repeat of a published prompt computes NOTHING on
    the prefill plane: the claim covers the whole prompt, the stored
    first token replays, and decode continues token-identically."""
    cfg, params, srv = share_server
    rng = random.Random(21)
    prompt = [rng.randrange(cfg.vocab_size) for _ in range(40)]

    first = srv.serve([_req(0, prompt)], timeout=120)
    s1 = srv.prefix_stats()
    again = srv.serve([_req(1, prompt)], timeout=120)
    s2 = srv.prefix_stats()

    assert len(first) == 1 and len(again) == 1
    assert again[0].tokens == first[0].tokens
    assert s2["prefill_chunks_run"] == s1["prefill_chunks_run"]
    assert s2["prefill_full_hits"] == s1["prefill_full_hits"] + 1
    assert s2["prefix_hit_tokens"] >= s1["prefix_hit_tokens"] + len(prompt)


@pytest.mark.slow
def test_shared_prefix_serving_token_exact_and_drains(share_server):
    """Multi-tenant wave (common 48-token prefix + an exact repeat)
    through the full server: token-exact vs the seed chunked-prefill +
    serial-decode oracle, with real page sharing and COW observed; after
    evicting the caches every pool is empty — nothing leaked."""
    import jax.numpy as jnp
    from repro.models import init_cache, prefill_chunk, decode_step

    cfg, params, srv = share_server
    rng = random.Random(9)
    prefix = [rng.randrange(cfg.vocab_size) for _ in range(48)]
    prompts = [prefix + [rng.randrange(cfg.vocab_size)
                         for _ in range(8 + i)] for i in range(4)]
    prompts.append(list(prompts[0]))            # exact repeat
    s0 = srv.prefix_stats()
    # two waves so wave 2 claims pages wave 1 published
    gens = list(srv.serve([_req(100 + i, p, t=i * 0.05)
                           for i, p in enumerate(prompts)], timeout=120))
    gens += srv.serve([_req(200 + i, p, t=i * 0.05)
                       for i, p in enumerate(prompts)], timeout=120)
    s1 = srv.prefix_stats()

    def oracle(ids):
        cache = init_cache(cfg, 1, MAX_LEN)
        for i in range(0, len(ids), 16):
            arr = jnp.asarray([ids[i:i + 16]], jnp.int32)
            logits, cache = prefill_chunk(cfg, params, arr, cache)
        toks = [int(jnp.argmax(logits[0]))]
        for _ in range(2):
            lg, cache = decode_step(
                cfg, params, jnp.asarray([[toks[-1]]], jnp.int32), cache)
            toks.append(int(jnp.argmax(lg[0])))
        return toks

    assert len(gens) == 2 * len(prompts)
    want = {i: oracle(p) for i, p in enumerate(prompts)}
    for g in gens:
        assert g.tokens == want[g.rid % 100], g.rid
    assert s1["prefix_hit_tokens"] > s0["prefix_hit_tokens"]
    assert s1["decode_blocks_shared"] > s0["decode_blocks_shared"]
    assert s1["decode_cow_copies"] > s0["decode_cow_copies"]

    # evicting the caches must surrender every page: the trees were the
    # only remaining holders once the requests finished
    for eng in srv.engines:
        assert eng.binder.ensure_free(eng.pool.num_blocks - 1)
        eng.pool.check()
        assert eng.pool.used_count == 0
    for eng in srv.decode_engines:
        for st_ in eng._dp.values():
            assert st_.binder.ensure_free(st_.pool.num_blocks - 1)
            st_.pool.check()
            assert st_.pool.used_count == 0
