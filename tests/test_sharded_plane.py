"""Sharded real plane: mesh-native engines must be TOKEN-EXACT vs the
single-device plane.

Multi-device cases run in subprocesses with
``--xla_force_host_platform_device_count`` forced (the count must be
pinned before jax initializes, and the suite's own process runs on the
normal 1-device platform), mirroring ``tests/test_distributed.py``.
Exactness holds because the engine meshes here are data-only (no tensor
parallelism, so no reduction-order drift) and the MoE capacity factor is
non-binding at these batch sizes — every token keeps its top-k experts
through the EP all-to-all path.
"""
import os
import subprocess
import sys

import pytest

from repro.serving.kv_pool import BlockPool

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

pytestmark = pytest.mark.sharded


def _sub(code: str, n_dev: int = 4, timeout: int = 420) -> str:
    env = {**os.environ, "PYTHONPATH": "src",
           "XLA_FLAGS": (f"--xla_force_host_platform_device_count={n_dev} "
                         + os.environ.get("XLA_FLAGS", ""))}
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env, cwd=ROOT)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


_PRELUDE = """
import jax, random
from repro.config.base import get_arch, ServingConfig
from repro.core.types import Request
from repro.launch.mesh import make_engine_mesh
from repro.models.model import init_params
from repro.serving.server import RealSBSServer

cfg = get_arch("granite-moe-1b-a400m", reduced=True)
params = init_params(cfg, jax.random.PRNGKey(0))

def reqs():
    rng = random.Random(7)
    return [Request(rid=i, input_len=16, output_len=5,
                    arrival_time=0.02 * i,
                    tokens=[rng.randrange(cfg.vocab_size)
                            for _ in range(16)])
            for i in range(6)]

def serve_pair(scfg):
    mesh = make_engine_mesh(4)
    srv_s = RealSBSServer(cfg, params, serving_cfg=scfg, scheduler="sbs",
                          max_len=64, max_new=5, mesh=mesh)
    gens_s = srv_s.serve(reqs(), timeout=120)
    srv_1 = RealSBSServer(cfg, params, serving_cfg=scfg, scheduler="sbs",
                          max_len=64, max_new=5)
    gens_1 = srv_1.serve(reqs(), timeout=120)
    ts = {g.rid: g.tokens for g in gens_s}
    t1 = {g.rid: g.tokens for g in gens_1}
    assert set(ts) == set(t1) == set(range(6)), (set(ts), set(t1))
    assert ts == t1, (ts, t1)
    return srv_s
"""


def test_sharded_pd_plane_token_exact():
    """P/D deployment on a 4-device data mesh (merged decode cache, EP
    all-to-all in every step) generates the SAME tokens as the
    single-device paged plane, end to end through the server."""
    _sub(_PRELUDE + """
scfg = ServingConfig(num_prefill_instances=1, prefill_dp_per_instance=1,
                     num_decode_instances=1, decode_dp_per_instance=4,
                     chunk_size=32, t_default=0.05, l_net=0.001,
                     max_batch_per_dp=2, block_size=8)
srv = serve_pair(scfg)
eng = srv.decode_engines[0]
assert eng.step_samples, "sharded decode never stepped"
# merged plane: every sample covers the whole instance-wide slot axis
assert all(r == len(eng._group.slots) for _d, _a, r in eng.step_samples)
print("PD-EXACT-OK")
""")


def test_sharded_mixed_plane_token_exact():
    """Unified mixed-batch deployment (chunked prefill piggybacked into
    the merged cross-DP step) is token-exact vs single-device, and the
    sharded leg actually exercised fused mixed steps."""
    _sub(_PRELUDE + """
scfg = ServingConfig(num_prefill_instances=1, prefill_dp_per_instance=1,
                     num_decode_instances=1, decode_dp_per_instance=4,
                     chunk_size=32, t_default=0.05, l_net=0.001,
                     max_batch_per_dp=2, block_size=8, mixed_batch=True,
                     mixed_chunk=32)
srv = serve_pair(scfg)
eng = srv.decode_engines[0]
assert eng.mixed_steps > 0, "no fused mixed step ran"
print("MIXED-EXACT-OK")
""")


def test_sharded_step_has_ep_all_to_all():
    """The compiled merged decode step is a genuine mesh program: the
    explicit EP all-to-all appears in its HLO, and the output cache
    stays sharded over the data axis."""
    _sub("""
import jax
import jax.numpy as jnp
from repro.config.base import get_arch
from repro.launch.mesh import make_engine_mesh
from repro.models.model import init_params
from repro.serving.real_engine import EngineSpec

cfg = get_arch("granite-moe-1b-a400m", reduced=True)
params = init_params(cfg, jax.random.PRNGKey(0))
spec = EngineSpec(cfg, params, max_len=64, max_batch=2, block_size=8,
                  mesh=make_engine_mesh(4))
cache = spec.merged_paged_cache()
toks = jnp.zeros((cache["cur"].shape[0], 1), jnp.int32)
hlo = spec.jit_paged_decode.lower(
    spec.params, toks, cache).compile().as_text()
assert "all-to-all" in hlo, "EP shard_map path not active"
_lg, out = spec.jit_paged_decode(spec.params, toks, cache)
assert "data" in str(out["cur"].sharding.spec), out["cur"].sharding
print("EP-HLO-OK")
""")


def test_deepseek_dry_run_shapes():
    """deepseek-v3-671b (reduced geometry, MLA + shared-expert MoE)
    lowers and compiles through the sharded decode step on a 4-device
    data mesh with the EP all-to-all active — the dry-run shape check of
    the production config's engine layout."""
    _sub("""
import jax
import jax.numpy as jnp
from repro.config.base import get_arch
from repro.launch.mesh import make_engine_mesh
from repro.models.model import init_params
from repro.serving.real_engine import EngineSpec

cfg = get_arch("deepseek-v3-671b", reduced=True)
params = init_params(cfg, jax.random.PRNGKey(0))
spec = EngineSpec(cfg, params, max_len=64, max_batch=2, block_size=8,
                  mesh=make_engine_mesh(4))
cache = spec.merged_paged_cache()
toks = jnp.zeros((cache["cur"].shape[0], 1), jnp.int32)
hlo = spec.jit_paged_decode.lower(
    spec.params, toks, cache).compile().as_text()
assert "all-to-all" in hlo, "EP path inactive for deepseek config"
print("DSV3-DRYRUN-OK")
""")


def test_block_pool_base_offsets():
    """Per-DP pools with disjoint base offsets issue GLOBAL block ids
    (the merged-cache contract: DP k owns [k*B, (k+1)*B))."""
    pools = [BlockPool(8, 4, base=k * 8) for k in range(3)]
    seen = set()
    for k, p in enumerate(pools):
        ids = p.alloc(p.free_count)
        assert all(k * 8 < i < (k + 1) * 8 for i in ids), (k, ids)
        assert not (set(ids) & seen)
        seen.update(ids)
        p.free(ids)
        p.check()
    with pytest.raises(ValueError):
        BlockPool(8, 4, base=-1)
