"""§3.2 — queuing-theory claim: immediate dispatch into saturated discrete-
batch engines waits T/2 on average (independent of N); staggering the batch
boundaries by T/N drops the expected wait to T/(2N)."""
import random

import pytest


def waits_immediate(n_inst, T, arrivals, rng):
    """Engines run back-to-back passes of period T (saturated). A request is
    bound to an instance on arrival and waits for its next batch boundary —
    inside the device queue, invisible to the scheduler."""
    phases = [rng.uniform(0, T) for _ in range(n_inst)]
    waits = []
    for i, t in enumerate(arrivals):
        k = i % n_inst                     # round-robin binding
        waits.append((phases[k] - t) % T)
    return waits


def waits_staggered(n_inst, T, arrivals):
    """SBS: boundaries staggered by T/N; the scheduler holds the request and
    dispatches at the NEXT boundary of ANY instance."""
    waits = []
    for t in arrivals:
        w = min((k * T / n_inst - t) % T for k in range(n_inst))
        waits.append(w)
    return waits


@pytest.mark.parametrize("n_inst", [4, 8, 16])
def test_t_over_2n(n_inst):
    rng = random.Random(0)
    T = 1.0
    arrivals = [rng.uniform(0, 1000.0) for _ in range(20_000)]
    w_imm = waits_immediate(n_inst, T, arrivals, rng)
    w_stag = waits_staggered(n_inst, T, arrivals)
    m_imm = sum(w_imm) / len(w_imm)
    m_stag = sum(w_stag) / len(w_stag)
    # immediate ≈ T/2 regardless of N
    assert m_imm == pytest.approx(T / 2, rel=0.05)
    # staggered ≈ T/(2N)
    assert m_stag == pytest.approx(T / (2 * n_inst), rel=0.05)
    # ⇒ order-of-magnitude reduction for N ≥ 10 (paper's claim)
    assert m_stag < m_imm / (n_inst / 1.2)


def test_immediate_wait_is_independent_of_cluster_size():
    rng = random.Random(1)
    T = 1.0
    arrivals = [rng.uniform(0, 1000.0) for _ in range(20_000)]
    means = []
    for n in (2, 32):
        w = waits_immediate(n, T, arrivals, random.Random(2))
        means.append(sum(w) / len(w))
    assert means[0] == pytest.approx(means[1], rel=0.1)
