"""System-level invariants of the cluster simulator (hypothesis-driven):
request conservation, metric bounds, FCFS-ish fairness under SBS."""
import pytest
from _hypothesis_shim import given, settings, st

from repro.config import ServingConfig, get_arch
from repro.core.types import RequestPhase
from repro.serving.cluster import DecodeClusterSim, PrefillClusterSim
from repro.serving.workload import WorkloadSpec, generate

CFG = get_arch("deepseek-7b")     # small cost model => fast sims


@given(
    qps=st.floats(5.0, 60.0),
    n_inst=st.integers(1, 4),
    n_dp=st.integers(1, 4),
    chunk=st.sampled_from([512, 2048, 4096]),
    sched=st.sampled_from(["sbs", "immediate-rr"]),
    seed=st.integers(0, 5),
)
@settings(max_examples=15, deadline=None)
def test_prefill_conservation_and_bounds(qps, n_inst, n_dp, chunk, sched,
                                         seed):
    scfg = ServingConfig(num_prefill_instances=n_inst,
                         prefill_dp_per_instance=n_dp, chunk_size=chunk,
                         t_default=0.2, n_limit=50)
    spec = WorkloadSpec("w", 16, 2000, 600.0)
    reqs = generate(spec, qps=qps, duration=4, seed=seed)
    if not reqs:
        return
    sim = PrefillClusterSim(CFG, scfg, scheduler=sched)
    rep = sim.run(reqs, 4)
    # conservation: every request is finished, flow-controlled, or still
    # tracked by the scheduler/engines (horizon cut an overloaded drain) —
    # none may simply vanish
    done = sum(1 for r in reqs if r.first_token_time is not None)
    rejected = sum(1 for r in reqs if r.phase == RequestPhase.REJECTED)
    in_sched = len(getattr(sim.sched, "buffer", [])) +         len(getattr(sim.sched, "pending", []))
    in_engine = sum(1 for r in reqs if r.first_token_time is None
                    and r.phase == RequestPhase.DISPATCHED)
    assert done + rejected + in_sched + in_engine >= len(reqs)
    assert done + rejected <= len(reqs)
    # bounds
    assert 0.0 <= rep.chunk_util <= 1.0
    for r in reqs:
        if r.first_token_time is not None:
            assert r.first_token_time >= r.arrival_time
            if r.dispatch_time is not None:
                assert r.dispatch_time + 1e-9 >= r.arrival_time
    # engine token accounting: processed >= completed requests' tokens
    # (flow control may reject a request AFTER partial chunks ran); the
    # excess is bounded by the unfinished requests' totals
    total_proc = sum(i.tokens_processed for i in sim.instances)
    total_done = sum(r.input_len for r in reqs
                     if r.first_token_time is not None)
    unfinished = sum(r.input_len for r in reqs
                     if r.first_token_time is None)
    assert total_done <= total_proc <= total_done + unfinished


@given(seed=st.integers(0, 4))
@settings(max_examples=5, deadline=None)
def test_decode_conservation(seed):
    scfg = ServingConfig(num_decode_instances=1, decode_dp_per_instance=8,
                         max_batch_per_dp=64, kv_budget_tokens=10**9)
    spec = WorkloadSpec("d", 64, 4096, 1000.0, out_mean=30)
    reqs = generate(spec, qps=2000, duration=1, seed=seed)[:300]
    sim = DecodeClusterSim(CFG, scfg, scheduler="sbs")
    rep = sim.run(reqs, 60, closed_loop=64)
    finished = [r for r in reqs if r.finish_time is not None]
    # every finished request generated exactly its output_len tokens
    for r in finished:
        assert r.generated == r.output_len
    assert rep.tokens_generated == sum(r.generated for r in reqs)
    # all admitted KV was released for finished requests (states consistent)
    live_kv = sum(d.kv_tokens for d in sim.state.decode_dps)
    live = [r for r in reqs if r.assigned_dp is not None
            and r.finish_time is None]
    expected_live = sum(r.input_len + r.generated for r in live)
    assert live_kv == expected_live


@pytest.mark.paged
@given(seed=st.integers(0, 4),
       block_size=st.sampled_from([16, 64, 256]),
       sched=st.sampled_from(["sbs", "sbs-la"]))
@settings(max_examples=6, deadline=None)
def test_decode_conservation_paged(seed, block_size, sched):
    """Sim plane with block-granular KV accounting: reserved blocks are
    conserved (admit = release), occupancy ≥ exact tokens at all times,
    and a drained cluster holds zero blocks — the same invariants the
    real paged engine's BlockPool enforces device-side."""
    scfg = ServingConfig(num_decode_instances=2, decode_dp_per_instance=4,
                         max_batch_per_dp=32, kv_budget_tokens=10**9,
                         block_size=block_size)
    spec = WorkloadSpec("d", 64, 4096, 1000.0, out_mean=30)
    reqs = generate(spec, qps=500, duration=1, seed=seed)[:150]
    sim = DecodeClusterSim(CFG, scfg, scheduler=sched)
    sim.run(reqs, 60, closed_loop=32)
    finished = [r for r in reqs if r.finish_time is not None]
    for r in finished:
        assert r.generated == r.output_len
    live = [r for r in reqs if r.assigned_dp is not None
            and r.finish_time is None]
    # exact-token accounting is unchanged by paging
    live_kv = sum(d.kv_tokens for d in sim.state.decode_dps)
    assert live_kv == sum(r.input_len + r.generated for r in live)
    # block accounting: reserved blocks == the live requests' lifetime
    # reservations; occupancy dominates the exact token load
    def blocks_for(r):
        total = r.input_len + r.output_len
        return -(-total // block_size)
    live_blocks = sum(d.kv_blocks for d in sim.state.decode_dps)
    assert live_blocks == sum(blocks_for(r) for r in live)
    for d in sim.state.decode_dps:
        assert d.kv_occupancy >= d.kv_tokens or not live
    if not live:
        assert live_blocks == 0


def test_sbs_no_starvation_under_moderate_load():
    """With n_limit high, all requests of a finite burst complete (liveness)."""
    scfg = ServingConfig(num_prefill_instances=2, prefill_dp_per_instance=2,
                         chunk_size=1024, t_default=0.2, n_limit=10**6)
    spec = WorkloadSpec("w", 100, 3000, 1200.0)
    reqs = generate(spec, qps=30, duration=3, seed=2)
    sim = PrefillClusterSim(CFG, scfg, scheduler="sbs")
    sim.run(reqs, 3)
    assert all(r.first_token_time is not None for r in reqs)
