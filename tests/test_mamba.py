"""Mamba2 SSD: chunked scan vs sequential recurrence; O(1) decode; kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.config.base import SSMConfig
from repro.models.mamba import (
    init_mamba_params, mamba_decode_step, mamba_forward, ssd_chunked,
    ssd_chunked_kernel, ssd_reference, ssm_dims,
)


def _inputs(key, B, S, nh, hp, ds):
    ks = jax.random.split(jax.random.PRNGKey(key), 4)
    x = jax.random.normal(ks[0], (B, S, nh, hp)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    A = -jnp.exp(jnp.linspace(0.0, 1.0, nh))
    Bm = jax.random.normal(ks[2], (B, S, 1, ds)) * 0.3
    Cm = jax.random.normal(ks[3], (B, S, 1, ds)) * 0.3
    return x, dt, A, Bm, Cm


@pytest.mark.parametrize("S,chunk", [(20, 8), (32, 32), (7, 16), (64, 16)])
def test_chunked_equals_sequential(S, chunk):
    x, dt, A, Bm, Cm = _inputs(0, 2, S, 4, 16, 8)
    y1, h1 = ssd_chunked(x, dt, A, Bm, Cm, chunk)
    y2, h2 = ssd_reference(x, dt, A, Bm, Cm)
    assert np.abs(np.asarray(y1 - y2)).max() < 1e-5
    assert np.abs(np.asarray(h1 - h2)).max() < 1e-5


def test_initial_state_carries():
    """Split-sequence chunked-prefill semantics: two halves with carried
    state == whole sequence."""
    x, dt, A, Bm, Cm = _inputs(1, 2, 24, 4, 16, 8)
    y, h = ssd_chunked(x, dt, A, Bm, Cm, 8)
    y1, h1 = ssd_chunked(x[:, :12], dt[:, :12], A, Bm[:, :12], Cm[:, :12], 8)
    y2, h2 = ssd_chunked(x[:, 12:], dt[:, 12:], A, Bm[:, 12:], Cm[:, 12:], 8,
                         initial_state=h1)
    assert np.abs(np.asarray(jnp.concatenate([y1, y2], 1) - y)).max() < 1e-5
    assert np.abs(np.asarray(h2 - h)).max() < 1e-5


def test_decode_step_equals_forward():
    sc = SSMConfig(d_state=16, head_dim=16, expand=2, chunk_size=8)
    D = 32
    p = init_mamba_params(jax.random.PRNGKey(0), D, sc, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 20, D)) * 0.5
    out, (hf, csf) = mamba_forward(x, p, sc)
    di, nh, cdim = ssm_dims(D, sc)
    gds2 = 2 * sc.n_groups * sc.d_state
    h = jnp.zeros((2, nh, sc.head_dim, sc.d_state), jnp.float32)
    cs = (jnp.zeros((2, sc.d_conv - 1, di), x.dtype),
          jnp.zeros((2, sc.d_conv - 1, gds2), x.dtype))
    outs = []
    for t in range(20):
        o, (h, cs) = mamba_decode_step(x[:, t:t + 1], p, sc, h, cs)
        outs.append(o)
    od = jnp.concatenate(outs, axis=1)
    assert np.abs(np.asarray(od - out)).max() < 1e-5
    assert np.abs(np.asarray(h - hf)).max() < 1e-5
    for a, b in zip(jax.tree.leaves(cs), jax.tree.leaves(csf)):
        assert np.abs(np.asarray(a - b)).max() < 1e-6


def test_kernel_path_equals_xla_path():
    x, dt, A, Bm, Cm = _inputs(2, 2, 52, 4, 32, 16)
    h0 = jax.random.normal(jax.random.PRNGKey(9), (2, 4, 32, 16)) * 0.2
    y1, h1 = ssd_chunked(x, dt, A, Bm, Cm, 16, h0)
    y2, h2 = ssd_chunked_kernel(x, dt, A, Bm, Cm, 16, h0)
    assert np.abs(np.asarray(y1 - y2)).max() < 1e-5
    assert np.abs(np.asarray(h1 - h2)).max() < 1e-5


@given(s=st.integers(2, 40), chunk=st.sampled_from([4, 8, 16]),
       nh=st.sampled_from([2, 4]))
@settings(max_examples=20, deadline=None)
def test_chunked_property(s, chunk, nh):
    x, dt, A, Bm, Cm = _inputs(s, 1, s, nh, 8, 4)
    y1, h1 = ssd_chunked(x, dt, A, Bm, Cm, chunk)
    y2, h2 = ssd_reference(x, dt, A, Bm, Cm)
    assert np.abs(np.asarray(y1 - y2)).max() < 1e-4
    assert np.abs(np.asarray(h1 - h2)).max() < 1e-4
