"""Property-testing compatibility layer.

`hypothesis` is not installable in the offline CI environment, so every
test module imports `given / settings / st` from here instead.  When the
real library is available it is used unchanged (shrinking, the database,
health checks — everything).  Otherwise a small deterministic fallback
drives each property with seeded pseudo-random examples: the same
properties are checked, example generation is reproducible run-to-run,
and a failing example's kwargs are attached to the assertion message.

Only the strategy surface the suite actually uses is implemented:
    st.integers(lo, hi)   st.floats(lo, hi)   st.booleans()
    st.sampled_from(seq)  st.lists(elem, min_size=, max_size=)
    st.tuples(*elems)     st.just(v)          strategy.map(f)
"""
from __future__ import annotations

try:                                          # pragma: no cover
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect
    import random
    import zlib

    HAVE_HYPOTHESIS = False
    _DEFAULT_MAX_EXAMPLES = 25

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)))

    class _St:
        """Mini `hypothesis.strategies` namespace."""

        @staticmethod
        def integers(min_value=0, max_value=2 ** 31 - 1):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(seq):
            items = list(seq)
            return _Strategy(lambda rng: items[rng.randrange(len(items))])

        @staticmethod
        def just(value):
            return _Strategy(lambda rng: value)

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.example(rng) for _ in range(n)]
            return _Strategy(draw)

        @staticmethod
        def tuples(*elements):
            return _Strategy(
                lambda rng: tuple(e.example(rng) for e in elements))

    st = _St()

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
        """Store the run budget on the function for `given` to pick up."""
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        """Deterministic replay: the RNG is seeded from the test name, so
        every run (and every CI machine) sees the same example stream."""
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(fn, "_shim_max_examples", _DEFAULT_MAX_EXAMPLES)
                rng = random.Random(zlib.crc32(fn.__name__.encode()))
                for i in range(n):
                    drawn = {k: s.example(rng)
                             for k, s in strategies.items()}
                    try:
                        fn(*args, **kwargs, **drawn)
                    except AssertionError as e:
                        raise AssertionError(
                            f"property failed on example #{i}: {drawn!r}"
                        ) from e
            # hide the drawn parameters from pytest's fixture resolution:
            # only non-strategy params (fixtures like monkeypatch) remain
            sig = inspect.signature(fn)
            keep = [p for name, p in sig.parameters.items()
                    if name not in strategies]
            del wrapper.__wrapped__
            wrapper.__signature__ = sig.replace(parameters=keep)
            return wrapper
        return deco
