import os
import sys

# NOTE: deliberately NO --xla_force_host_platform_device_count here — tests
# and benches must see the real (1-device) platform; only launch/dryrun.py
# forces 512 host devices (in its own process).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
