import os
import sys

import pytest

# NOTE: deliberately NO --xla_force_host_platform_device_count here — tests
# and benches must see the real (1-device) platform; only launch/dryrun.py
# forces 512 host devices (in its own process).
_HERE = os.path.dirname(__file__)
sys.path.insert(0, os.path.join(_HERE, "..", "src"))
sys.path.insert(0, _HERE)           # sibling imports (_hypothesis_shim)


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: heavyweight model/jit cases (deselect with "
        "-m 'not slow' for the fast tier-1 loop)")
    config.addinivalue_line(
        "markers", "paged: paged (block-table) KV cache suite — the "
        "allocator/cache-surgery property tests run in the fast tier "
        "(scripts/ci.sh); the heavyweight cross-plane equivalence sweep "
        "is additionally @slow and only runs under --full")
    config.addinivalue_line(
        "markers", "mixed: unified mixed-batch plane suite (Sarathi-style "
        "piggybacking + length-bucketed formation) — runs FIRST in the "
        "fast tier (scripts/ci.sh), before the paged suite")
    config.addinivalue_line(
        "markers", "sharded: mesh-native real-plane suite — multi-device "
        "cases run in subprocesses with forced host devices (the device "
        "count must be pinned before jax initializes), so the suite is "
        "offline-safe under the normal 1-device platform")


# ---------------------------------------------------------------------------
# Memory-mapping guard.  Every jitted computation XLA:CPU compiles keeps
# LLVM ORC JIT code pages mapped for the life of the executable, several
# small mappings each; a full -x -q run accumulates tens of thousands and
# a process that crosses the kernel's vm.max_map_count (65530 default)
# SEGFAULTS inside the next backend_compile — the mmap failure surfaces
# as a crash, not an exception.  Dropping the jit caches at module
# boundaries frees the code pages (recompilation on next use is the only
# cost), so the suite's mapping footprint is bounded by its heaviest
# single module instead of its sum.
# ---------------------------------------------------------------------------

_MAPS_SOFT_LIMIT = 20_000


def _n_mappings() -> int:
    try:
        with open("/proc/self/maps") as f:
            return sum(1 for _ in f)
    except OSError:                     # non-Linux: nothing to guard
        return 0


@pytest.fixture(autouse=True, scope="module")
def _bound_jit_code_mappings():
    yield
    if _n_mappings() > _MAPS_SOFT_LIMIT:
        import jax
        jax.clear_caches()


# ---------------------------------------------------------------------------
# Shared, session-scoped model setup. get_arch() is cheap but init_params +
# the first jitted forward of each (arch, shape) pair dominates the suite's
# runtime — cache them once per session instead of once per test.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="session")
def model_setup():
    """(arch, B, S, key) -> (cfg, params, tokens, embeds, full_logits, npre),
    memoized for the whole session."""
    import jax
    import jax.numpy as jnp
    from repro.config import get_arch
    from repro.models import init_params
    from repro.models.model import forward_full, logits_from_hidden

    cache = {}

    def get(arch, B=2, S=16, key=0):
        k = (arch, B, S, key)
        if k in cache:
            return cache[k]
        cfg = get_arch(arch, reduced=True)
        params = init_params(cfg, jax.random.PRNGKey(key))
        ks = jax.random.split(jax.random.PRNGKey(key + 1), 2)
        tokens = jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)
        embeds = None
        if cfg.is_encoder_decoder:
            embeds = jax.random.normal(
                ks[1], (B, cfg.encoder_seq_len, cfg.d_model)) * 0.1
        elif cfg.num_patch_tokens:
            embeds = jax.random.normal(
                ks[1], (B, cfg.num_patch_tokens, cfg.d_model)) * 0.1
        x, _, _, _ = forward_full(cfg, params, tokens, embeds=embeds)
        full_logits = logits_from_hidden(cfg, params, x)
        npre = x.shape[1] - S
        cache[k] = (cfg, params, tokens, embeds, full_logits, npre)
        return cache[k]
    return get
