"""Training substrate: optimizer, schedules, data pipeline, checkpointing."""
import math
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.config import TrainConfig, get_arch
from repro.data import pack_documents, synthetic_batches
from repro.data.synthetic import SyntheticLM
from repro.train import Trainer, adamw_init, adamw_update, make_schedule
from repro.train.optimizer import clip_by_global_norm


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params)
    for _ in range(300):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt, _ = adamw_update(params, grads, opt, lr=5e-2,
                                      weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_grad_clip():
    g = {"a": jnp.asarray([3.0, 4.0])}          # norm 5
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(5.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0)


def test_wsd_schedule_shape():
    fn = make_schedule("wsd", 1e-3, warmup_steps=10, total_steps=100,
                       stable_frac=0.8)
    assert float(fn(0)) == 0.0
    assert float(fn(10)) == pytest.approx(1e-3)
    assert float(fn(50)) == pytest.approx(1e-3)       # stable plateau
    assert float(fn(99)) < 0.5e-3                     # decay tail
    assert float(fn(79)) == pytest.approx(1e-3)


def test_synthetic_lm_is_learnable_structure():
    lm = SyntheticLM(vocab=64, branching=4, seed=0)
    rng = np.random.default_rng(0)
    doc = lm.sample_doc(128, rng)
    # every transition is one of the 4 successors
    for a, b in zip(doc[:-1], doc[1:]):
        assert b in lm.table[a]
    assert lm.optimal_ce() == pytest.approx(math.log(4))


def test_packing_segments_and_targets():
    docs = [np.arange(5), np.arange(3), np.arange(7)]
    out = pack_documents(docs, seq_len=8)
    assert out["tokens"].shape[1] == 8
    # boundaries: last token of each segment has target -100
    for i in range(out["tokens"].shape[0]):
        seg = out["seg"][i]
        for j in range(8):
            if seg[j] >= 0 and (j == 7 or seg[j + 1] != seg[j]):
                assert out["targets"][i, j] == -100
    # positions restart per segment
    assert (out["positions"][out["seg"] == 0][:3] == [0, 1, 2]).all()


def test_trainer_loss_decreases_and_restores():
    cfg = get_arch("granite-moe-1b-a400m", reduced=True)
    tcfg = TrainConfig(global_batch=4, seq_len=32, lr=3e-3, total_steps=40,
                       warmup_steps=5, schedule="wsd")
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(cfg, tcfg, ckpt_dir=d)
        batches = synthetic_batches(cfg.vocab_size, 4, 32, branching=4)
        res = tr.fit(batches, steps=40, log_every=10,
                     log_fn=lambda s: None)
        hist = res["history"]
        assert hist[-1][1] < hist[0][1]          # CE decreases
        tr.save()
        tr2 = Trainer(cfg, tcfg, ckpt_dir=d)
        assert tr2.step == 40
        for a, b in zip(jax.tree.leaves(tr.params),
                        jax.tree.leaves(tr2.params)):
            assert np.allclose(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_validation():
    with tempfile.TemporaryDirectory() as d:
        tree = {"w": np.zeros((2, 3), np.float32)}
        save_checkpoint(d, 5, tree)
        assert latest_step(d) == 5
        bad = {"w": np.zeros((3, 3), np.float32)}
        with pytest.raises(ValueError):
            load_checkpoint(d, bad)
        missing = {"v": np.zeros((2, 3), np.float32)}
        with pytest.raises(KeyError):
            load_checkpoint(d, missing)
