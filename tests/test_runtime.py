"""Invariants of the unified ClusterRuntime event loop, the load-aware
decode allocator, and the watchdog re-dispatch path."""
import pytest

from _hypothesis_shim import given, settings, st

from repro.config import ServingConfig, get_arch
from repro.core.decode_alloc import schedule_decode_global
from repro.core.scheduler import DecodeScheduler
from repro.core.types import DecodeDPState, Request
from repro.serving.cluster import (
    DecodeClusterSim, PrefillClusterSim, build_state,
)
from repro.serving.e2e import PDClusterSim
from repro.serving.engine import SimDecodeInstance
from repro.serving.runtime import ClusterRuntime
from repro.serving.workload import (
    BURSTY, HEAVY_TAIL, WorkloadSpec, generate,
)

CFG = get_arch("deepseek-7b")


def _pd_cfg():
    return ServingConfig(num_prefill_instances=2, prefill_dp_per_instance=4,
                         num_decode_instances=2, decode_dp_per_instance=4,
                         chunk_size=2048, t_default=0.3,
                         max_batch_per_dp=64, kv_budget_tokens=400_000)


# ---------------------------------------------------------------------------
# One runtime behind every simulator
# ---------------------------------------------------------------------------

def test_all_three_sims_delegate_to_cluster_runtime():
    scfg = _pd_cfg()
    p = PrefillClusterSim(CFG, scfg)
    d = DecodeClusterSim(CFG, scfg)
    e = PDClusterSim(CFG, scfg)
    assert isinstance(p.runtime, ClusterRuntime)
    assert isinstance(d.runtime, ClusterRuntime)
    assert isinstance(e.runtime, ClusterRuntime)
    # no duplicated event-loop machinery left in the wrappers
    import repro.serving.cluster as cluster_mod
    import repro.serving.e2e as e2e_mod
    assert not hasattr(cluster_mod, "heapq")
    assert not hasattr(e2e_mod, "heapq")


def test_pd_pipeline_conserves_requests_exactly_once():
    """Every arrived request finishes exactly once — finish_time set,
    generated == output_len, and token accounting is additive."""
    spec = WorkloadSpec("w", 64, 2000, 700.0, out_mean=20)
    reqs = generate(spec, qps=20, duration=5, seed=3)
    sim = PDClusterSim(CFG, _pd_cfg(), scheduler="sbs")
    sim.run(reqs, 5, slo_e2e=60.0)
    assert all(r.finish_time is not None for r in reqs)
    for r in reqs:
        assert r.generated == r.output_len          # exactly-once decode
        assert r.first_token_time is not None
        assert r.arrival_time <= r.first_token_time <= r.finish_time
    total = sum(i.tokens_generated for i in sim.decode)
    assert total == sum(r.output_len for r in reqs)


def test_no_dispatch_to_non_quiescent_instance(monkeypatch):
    """With feedback flowing (no lost signals), SBS never enqueues work on
    an engine that is mid-pass — quiescence gating holds end-to-end."""
    from repro.serving.engine import SimPrefillInstance
    violations = []
    orig = SimPrefillInstance.enqueue

    def checked(self, cmd, now):
        if self.busy:
            violations.append((self.instance_id, now))
        return orig(self, cmd, now)

    monkeypatch.setattr(SimPrefillInstance, "enqueue", checked)
    scfg = ServingConfig(num_prefill_instances=3, prefill_dp_per_instance=2,
                         chunk_size=2048, t_default=0.2, n_limit=10 ** 6)
    reqs = generate(WorkloadSpec("w", 64, 2000, 700.0), qps=40, duration=5,
                    seed=4)
    PrefillClusterSim(CFG, scfg, scheduler="sbs").run(reqs, 5)
    assert not violations


def test_decode_only_runtime_matches_closed_loop_semantics():
    scfg = ServingConfig(num_decode_instances=2, decode_dp_per_instance=4,
                         max_batch_per_dp=64, kv_budget_tokens=10 ** 9)
    spec = WorkloadSpec("d", 64, 2048, 800.0, out_mean=20)
    reqs = generate(spec, qps=2000, duration=1, seed=5)[:200]
    sim = DecodeClusterSim(CFG, scfg, scheduler="sbs-la")
    rep = sim.run(reqs, 60, closed_loop=32)
    assert rep.tokens_generated == sum(r.generated for r in reqs)
    for r in reqs:
        if r.finish_time is not None:
            assert r.generated == r.output_len


# ---------------------------------------------------------------------------
# Load-Aware Global Allocation
# ---------------------------------------------------------------------------

def mk_units(n_inst, per_inst, kv=0):
    units = []
    for i in range(n_inst):
        for j in range(per_inst):
            units.append(DecodeDPState(dp_id=i * per_inst + j,
                                       instance_id=i, kv_tokens=kv))
    return units


def mk_req(rid, in_len, out_len=10):
    return Request(rid=rid, arrival_time=0.0, input_len=in_len,
                   output_len=out_len)


@given(
    lens=st.lists(st.integers(1, 20_000), min_size=1, max_size=64),
    n_inst=st.integers(1, 4),
    per_inst=st.integers(1, 8),
)
@settings(max_examples=40, deadline=None)
def test_load_aware_greedy_balance_bound(lens, n_inst, per_inst):
    """From an empty pool, greedy least-KV placement keeps the per-DP
    KV spread within the largest single placement (list-scheduling
    bound), and every request lands exactly once."""
    units = mk_units(n_inst, per_inst)
    reqs = [mk_req(i, l) for i, l in enumerate(lens)]
    out = schedule_decode_global(reqs, units)
    assigned = sorted(r.rid for v in out.values() for r in v)
    assert assigned == sorted(r.rid for r in reqs)
    assert sum(u.kv_tokens for u in units) == sum(lens)
    assert sum(u.batch for u in units) == len(lens)
    spread = max(u.kv_tokens for u in units) - min(
        u.kv_tokens for u in units)
    assert spread <= max(r.input_len + r.generated for r in reqs)


def test_load_aware_balances_across_instances():
    """A pre-loaded hot instance sheds new traffic to its cold peer."""
    units = mk_units(2, 4, kv=0)
    for u in units:
        if u.instance_id == 0:
            u.kv_tokens = 50_000                 # instance 0 is hot
    out = schedule_decode_global([mk_req(i, 1000) for i in range(8)], units)
    placed_inst = {u.instance_id for u in units
                   for dp in out if dp == u.dp_id}
    assert placed_inst == {1}


def test_load_aware_respects_exclusion_with_fallback():
    units = mk_units(2, 2)
    out = schedule_decode_global([mk_req(0, 100)], units,
                                 exclude_instances=frozenset({0}))
    assert all(units[dp].instance_id == 1 for dp in out)
    # excluding everything must not drop work
    out2 = schedule_decode_global([mk_req(1, 100)], units,
                                  exclude_instances=frozenset({0, 1}))
    assert sum(len(v) for v in out2.values()) == 1


# ---------------------------------------------------------------------------
# Watchdog re-dispatch
# ---------------------------------------------------------------------------

def test_watchdog_redispatches_off_stalled_instance():
    scfg = ServingConfig(num_decode_instances=2, decode_dp_per_instance=2,
                         max_batch_per_dp=64, kv_budget_tokens=10 ** 9)
    state = build_state(scfg)
    sched = DecodeScheduler(state, mode="sbs", alloc="load_aware",
                            watchdog_multiplier=5.0)
    from repro.serving.costmodel import CostModel
    cost = CostModel(CFG)
    insts = [SimDecodeInstance(i, [d.dp_id for d in state.decode_dps_of(i)],
                               cost) for i in range(2)]
    rt = ClusterRuntime(state, decode_sched=sched, decode_instances=insts)
    # hand two requests to the scheduler and place them (lands on inst 0+1)
    for i in range(4):
        sched.on_handoff(mk_req(i, 1000), 0.0)
    rt._place(sched.poll(0.0), 0.0)
    assert insts[0].has_work() and insts[1].has_work()
    # instance 1 keeps stepping (healthy); instance 0 never reports.
    # the observed step time arms the watchdog budget
    sched.on_step_end(1, 0.05, step_time=0.05)
    kv_before = sum(d.kv_tokens for d in state.decode_dps)
    late = 10.0                       # way past 5 × step estimate
    placements = rt._redispatch_stalled(late)
    rt._place(placements, late)
    assert 0 in sched.quarantined
    assert not insts[0].has_work()    # drained
    assert insts[1].has_work()
    # every request still lives somewhere, KV accounting conserved
    n_running = sum(len(v) for v in insts[1].running.values())
    assert n_running == 4
    migrated = [r for v in insts[1].running.values() for r in v
                if r.migrations == 1]
    assert len(migrated) == 2         # exactly the two evicted requests
    assert sum(d.kv_tokens for d in state.decode_dps) == kv_before
    assert all(d.kv_tokens == 0 for d in state.decode_dps
               if d.instance_id == 0)
    # a healthy step un-quarantines the instance
    sched.on_step_end(0, late + 0.1)
    assert 0 not in sched.quarantined


def test_live_watchdog_run_terminates_and_conserves():
    """An armed watchdog driven through the real event loop must neither
    crash on stale step_end events nor livelock, even with an absurdly
    aggressive budget that preempts in-flight steps (such a budget cannot
    guarantee progress for every request — but no request may vanish)."""
    scfg = ServingConfig(num_decode_instances=2, decode_dp_per_instance=2,
                         max_batch_per_dp=64, kv_budget_tokens=10 ** 9)
    spec = WorkloadSpec("d", 64, 1024, 400.0, out_mean=4)
    reqs = generate(spec, qps=200, duration=0.5, seed=5)[:40]
    sim = DecodeClusterSim(CFG, scfg, scheduler="sbs-la",
                           watchdog_multiplier=0.5)
    sim.run(reqs, 0.5)
    # the aggressive budget really did exercise the re-dispatch path
    assert sum(r.migrations for r in reqs) > 0
    resident = [r for inst in sim.instances
                for v in inst.running.values() for r in v]
    for r in reqs:
        if r.finish_time is not None:
            assert r.generated == r.output_len    # exactly-once completion
        else:                                     # still resident, not lost
            assert r in resident or r in sim.sched.buffer
    # conservation: live KV accounting matches the resident requests
    live_kv = sum(d.kv_tokens for d in sim.state.decode_dps)
    assert live_kv == sum(r.input_len + r.generated for r in resident)


def test_live_watchdog_sane_budget_no_spurious_migrations():
    """With the paper's 5× budget and healthy instances, the watchdog
    must never preempt legitimate in-flight steps."""
    scfg = ServingConfig(num_decode_instances=2, decode_dp_per_instance=2,
                         max_batch_per_dp=64, kv_budget_tokens=10 ** 9)
    spec = WorkloadSpec("d", 64, 1024, 400.0, out_mean=5)
    reqs = generate(spec, qps=200, duration=0.5, seed=6)[:40]
    sim = DecodeClusterSim(CFG, scfg, scheduler="sbs-la",
                           watchdog_multiplier=5.0)
    sim.run(reqs, 2)
    assert all(r.finish_time is not None for r in reqs)
    assert sum(r.migrations for r in reqs) == 0


def test_load_aware_instance_load_counts_masked_units():
    """A hot instance whose saturated DPs are IQR/budget-masked must not
    look cold at level 1 — masked units still pace its sync barrier."""
    units = [DecodeDPState(dp_id=j, instance_id=0, kv_tokens=200_000,
                           kv_budget=150_000) for j in range(3)]
    units.append(DecodeDPState(dp_id=3, instance_id=0, kv_tokens=0))
    units += [DecodeDPState(dp_id=4 + j, instance_id=1, kv_tokens=10_000)
              for j in range(4)]
    out = schedule_decode_global([mk_req(0, 100)], units)
    (dp,) = out
    assert units[dp].instance_id == 1


def test_quarantine_lifts_after_probation():
    """A drained instance receives no work and so can never step itself
    healthy — probation must re-admit it after one further budget."""
    scfg = ServingConfig(num_decode_instances=2, decode_dp_per_instance=2,
                         max_batch_per_dp=64, kv_budget_tokens=10 ** 9)
    state = build_state(scfg)
    sched = DecodeScheduler(state, mode="sbs", alloc="load_aware",
                            watchdog_multiplier=5.0)
    sched.on_step_end(1, 0.05, step_time=0.05)     # arm the budget
    sched.on_placed({0: [mk_req(0, 100)]}, 0.1)
    assert sched.stalled_instances(10.0) == [0]
    assert 0 in sched.quarantined
    # before probation expires the instance stays excluded
    assert sched.stalled_instances(10.1) == []
    assert 0 in sched.quarantined
    # one further budget later it is re-admitted for probing
    sched.stalled_instances(10.0 + 5 * 0.05 + 1e-6)
    assert 0 not in sched.quarantined


def test_watchdog_unarmed_until_first_real_step():
    """Cold start: the default step estimate must not trip the watchdog
    before any real step time has been observed."""
    scfg = ServingConfig(num_decode_instances=2, decode_dp_per_instance=2,
                         max_batch_per_dp=64, kv_budget_tokens=10 ** 9)
    state = build_state(scfg)
    sched = DecodeScheduler(state, mode="sbs", alloc="load_aware",
                            watchdog_multiplier=5.0)
    sched.on_placed({0: [mk_req(0, 100)]}, 0.0)
    assert sched.stalled_instances(100.0) == []    # not armed yet
    sched.on_step_end(1, 0.5, step_time=0.5)       # first real sample
    assert sched.stalled_instances(100.0) == [0]


def test_stalled_instance_receives_no_new_work():
    scfg = ServingConfig(num_decode_instances=2, decode_dp_per_instance=2,
                         max_batch_per_dp=64, kv_budget_tokens=10 ** 9)
    state = build_state(scfg)
    sched = DecodeScheduler(state, mode="sbs", alloc="load_aware",
                            watchdog_multiplier=5.0)
    sched.quarantined.add(0)
    out = sched._allocate([mk_req(i, 100) for i in range(6)])
    dp2inst = {d.dp_id: d.instance_id for d in state.decode_dps}
    assert all(dp2inst[dp] == 1 for dp in out)


# ---------------------------------------------------------------------------
# Workload scenarios
# ---------------------------------------------------------------------------

def test_bursty_long_run_rate_matches_qps():
    reqs = generate(BURSTY, qps=50, duration=40, seed=9)
    rate = len(reqs) / 40
    assert 40 < rate < 60                   # long-run average preserved
    # and the process is actually bursty: peak-second rate >> mean rate
    per_sec = [0] * 40
    for r in reqs:
        per_sec[int(r.arrival_time)] += 1
    assert max(per_sec) > 1.8 * rate


def test_heavy_tail_has_heavy_tail():
    reqs = generate(HEAVY_TAIL, qps=200, duration=20, seed=10)
    lens = sorted(r.input_len for r in reqs)
    p50 = lens[len(lens) // 2]
    p99 = lens[int(len(lens) * 0.99)]
    assert p99 > 8 * p50                    # long-context outliers exist
    assert max(lens) <= HEAVY_TAIL.max_len
    assert min(lens) >= HEAVY_TAIL.min_len


def test_bursty_overcommitted_config_rejected():
    bad = WorkloadSpec("b", 16, 100, 50.0, burst_factor=5.0, burst_duty=0.3)
    with pytest.raises(ValueError):
        generate(bad, qps=10, duration=1, seed=0)


def test_workloads_deterministic_by_seed():
    a = generate(BURSTY, qps=30, duration=5, seed=1)
    b = generate(BURSTY, qps=30, duration=5, seed=1)
    assert [(r.arrival_time, r.input_len) for r in a] == \
        [(r.arrival_time, r.input_len) for r in b]
