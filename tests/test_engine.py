"""Simulated engine semantics: non-preemptive gated batching, backlog
accounting, chunk-utilization bookkeeping, decode stepping."""
import pytest

from repro.config import get_arch
from repro.core.types import DecodeDPState, DispatchCommand, Request
from repro.serving.costmodel import CostModel
from repro.serving.engine import SimDecodeInstance, SimPrefillInstance


COST = CostModel(get_arch("deepseek-7b"))


def _cmd(inst, assignments):
    return DispatchCommand(instance_id=inst, assignments=assignments)


def _req(rid, n):
    r = Request(rid=rid, arrival_time=0.0, input_len=n)
    # the scheduler decrements remaining_prefill when it grants tokens;
    # these tests model fully-granted requests
    r.remaining_prefill = 0
    return r


def test_pass_is_nonpreemptive_and_chunk_bounded():
    eng = SimPrefillInstance(0, [0, 1], chunk=100, cost=COST)
    r = _req(0, 250)
    eng.enqueue(_cmd(0, {0: [(r, 250)]}), 0.0)
    dur = eng.start_pass(0.0)
    assert dur is not None and eng.busy
    assert eng.start_pass(0.0) is None           # locked while running
    res = eng.finish_pass(dur)
    assert res.processed_per_dp[0] == 100        # chunk-bounded take
    assert res.end_forwards[0].remaining_tokens == 150   # backlog reported
    assert not res.completed                     # not done yet
    # two more passes drain it and complete the request
    for _ in range(2):
        d = eng.start_pass(0.0)
        res = eng.finish_pass(d)
    assert [r_.rid for r_ in res.completed] == [0]
    assert r.first_token_time is not None


def test_chunk_utilization_accounting():
    eng = SimPrefillInstance(0, [0, 1], chunk=100, cost=COST)
    eng.enqueue(_cmd(0, {0: [(_req(0, 60), 60)]}), 0.0)
    d = eng.start_pass(0.0)
    eng.finish_pass(d)
    # 60 tokens over 2 DPs × 100 capacity
    assert eng.chunk_utilization == pytest.approx(0.3)


def test_straggler_dp_sets_pass_time():
    eng = SimPrefillInstance(0, [0, 1], chunk=3072, cost=COST)
    eng.enqueue(_cmd(0, {0: [(_req(0, 3000), 3000)],
                          1: [(_req(1, 100), 100)]}), 0.0)
    d_skew = eng.start_pass(0.0)
    eng.finish_pass(d_skew)
    eng2 = SimPrefillInstance(1, [0, 1], chunk=3072, cost=COST)
    eng2.enqueue(_cmd(1, {0: [(_req(2, 1550), 1550)],
                           1: [(_req(3, 1550), 1550)]}), 0.0)
    d_bal = eng2.start_pass(0.0)
    # same total tokens; the skewed pass is slower (sync barrier on max DP)
    assert d_skew > d_bal


def test_zero_token_grant_completes_cached_request():
    eng = SimPrefillInstance(0, [0], chunk=100, cost=COST)
    r = _req(0, 50)
    eng.enqueue(_cmd(0, {0: [(r, 0)]}), 0.0)     # full prefix-cache hit
    d = eng.start_pass(0.0)
    res = eng.finish_pass(d)
    assert res.completed == [r]


def test_decode_instance_generates_and_releases():
    states = [DecodeDPState(dp_id=0, instance_id=0),
              DecodeDPState(dp_id=1, instance_id=0)]
    eng = SimDecodeInstance(0, [0, 1], COST)
    r = Request(rid=0, arrival_time=0.0, input_len=100, output_len=2)
    states[0].admit(100)
    eng.admit(0, r)
    d = eng.start_step(states)
    fin = eng.finish_step(d, states)
    assert not fin and r.generated == 1
    assert r.first_token_time is not None
    d = eng.start_step(states)
    fin = eng.finish_step(2 * d, states)
    assert fin == [r]
    assert states[0].batch == 0                   # KV released
    assert eng.tokens_generated == 2
