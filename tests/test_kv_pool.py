"""Property suite for the paged KV subsystem (offline-safe via
tests/_hypothesis_shim).

Three contract layers:
  1. `BlockPool` allocator — random alloc/free sequences never leak or
     double-allocate blocks, over-allocation raises, freed pages are
     reusable (lowest-id-first, deterministically).
  2. Cache surgery — `paged_cache_take(paged_cache_join(dst, src, slot),
     slot)` round-trips token-exactly, including onto freshly REUSED
     pages still holding a previous occupant's data.
  3. The null block — inactive batch rows scatter into physical block 0
     without perturbing live rows.
"""
import random

import pytest
from _hypothesis_shim import given, settings, st

from repro.serving.kv_pool import (
    NULL_BLOCK, BlockPool, OutOfBlocks, pad_block_table,
)

pytestmark = pytest.mark.paged


# ---------------------------------------------------------------------------
# 1. Allocator invariants
# ---------------------------------------------------------------------------

@given(
    num_blocks=st.integers(2, 40),
    block_size=st.sampled_from([1, 4, 16]),
    ops=st.lists(st.tuples(st.booleans(), st.integers(0, 9)),
                 min_size=1, max_size=60),
    seed=st.integers(0, 99),
)
@settings(max_examples=40, deadline=None)
def test_pool_never_leaks_or_double_allocates(num_blocks, block_size, ops,
                                              seed):
    """Drive a random alloc/free schedule; after every operation the pool
    must conserve blocks exactly (free ⊎ used = all non-null blocks)."""
    pool = BlockPool(num_blocks, block_size)
    rng = random.Random(seed)
    held = []                                   # list of alloc'd id-lists
    for is_alloc, n in ops:
        if is_alloc:
            if n > pool.free_count:
                with pytest.raises(OutOfBlocks):
                    pool.alloc(n)
            else:
                ids = pool.alloc(n)
                assert len(ids) == n
                assert NULL_BLOCK not in ids
                held.append(ids)
        elif held:
            ids = held.pop(rng.randrange(len(held)))
            pool.free(ids)
            with pytest.raises(ValueError):     # double-free must raise
                pool.free(ids[:1] if ids else [0])
        pool.check()
        allocated = [b for lst in held for b in lst]
        assert len(set(allocated)) == len(allocated), "double-allocated id"
        assert pool.used_count == len(allocated)
    for ids in held:
        pool.free(ids)
    pool.check()
    assert pool.free_count == num_blocks - 1    # everything came back


@given(n=st.integers(1, 20), seed=st.integers(0, 50))
@settings(max_examples=25, deadline=None)
def test_freed_pages_are_reusable_lowest_first(n, seed):
    """Freeing returns pages to circulation: a full drain/refill cycle
    hands back exactly the same ids (deterministic lowest-first)."""
    pool = BlockPool(32, 8)
    first = pool.alloc(n)
    rng = random.Random(seed)
    scrambled = list(first)
    rng.shuffle(scrambled)
    pool.free(scrambled)
    pool.check()
    assert pool.alloc(n) == first


def test_pool_rejects_degenerate_geometry():
    with pytest.raises(ValueError):
        BlockPool(1, 16)                        # only the null block
    with pytest.raises(ValueError):
        BlockPool(8, 0)
    pool = BlockPool(4, 16)
    with pytest.raises(ValueError):
        pool.free([NULL_BLOCK])                 # the null block is eternal
    with pytest.raises(ValueError):
        pool.free([2])                          # never issued
    assert pool.blocks_for(0) == 0
    assert pool.blocks_for(1) == 1
    assert pool.blocks_for(16) == 1
    assert pool.blocks_for(17) == 2
    assert pool.capacity_tokens == 3 * 16


def test_pad_block_table():
    assert pad_block_table([3, 5], 4) == [3, 5, -1, -1]
    assert pad_block_table([], 2) == [-1, -1]
    with pytest.raises(ValueError):
        pad_block_table([1, 2, 3], 2)


# ---------------------------------------------------------------------------
# 2. Cache-surgery round trip (join -> take is token-exact)
# ---------------------------------------------------------------------------

MAX_LEN, BS = 64, 16


@pytest.fixture(scope="module")
def paged_setup():
    import jax
    import jax.numpy as jnp
    from repro.config import get_arch
    from repro.models import init_cache, init_params, prefill_chunk

    cfg = get_arch("deepseek-7b", reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(0))

    def prefill(ids):
        cache = init_cache(cfg, 1, MAX_LEN)
        for i in range(0, len(ids), 16):
            arr = jnp.asarray([ids[i:i + 16]], jnp.int32)
            logits, cache = prefill_chunk(cfg, params, arr, cache)
        return int(jnp.argmax(logits[0])), cache

    return cfg, params, prefill


def _assert_roundtrip(cfg, src, taken):
    """taken == src on every VALID kv position (invalid slots may differ:
    the pool reuses pages and never scrubs them)."""
    import jax
    import numpy as np

    src_pos = np.asarray(src["kv_pos"][0])
    out_pos = np.asarray(taken["kv_pos"][0])
    np.testing.assert_array_equal(out_pos, src_pos)
    assert int(taken["cur"][0]) == int(src["cur"][0])
    valid = src_pos >= 0

    def check(a, b):
        a, b = np.asarray(a), np.asarray(b)
        if a.ndim >= 3 and a.shape[2] == src_pos.shape[0]:   # (n,1,S,...)
            np.testing.assert_array_equal(a[:, :, valid], b[:, :, valid])
        else:
            np.testing.assert_array_equal(a, b)

    jax.tree.map(check, src["blocks"], taken["blocks"])


@given(
    lengths=st.lists(st.integers(1, MAX_LEN - 1), min_size=1, max_size=3),
    seed=st.integers(0, 1000),
)
@settings(max_examples=5, deadline=None)
def test_join_take_roundtrip_token_exact(paged_setup, lengths, seed):
    """cache_take(cache_join(dst, src, slot), slot) recovers src exactly,
    for several requests sharing one pool — including pages reused from
    earlier (freed) occupants."""
    import jax.numpy as jnp
    from repro.models import (
        init_paged_cache, paged_cache_clear_slot, paged_cache_join,
        paged_cache_take,
    )

    cfg, params, prefill = paged_setup
    rng = random.Random(seed)
    slots, nbt = 4, MAX_LEN // BS
    pool = BlockPool(2 * nbt + 1, BS)
    pc = init_paged_cache(cfg, slots, pool.num_blocks, MAX_LEN, BS)
    for i, L in enumerate(lengths):
        ids = [rng.randrange(cfg.vocab_size) for _ in range(L)]
        _, src = prefill(ids)
        blocks = pool.alloc(pool.blocks_for(L))
        slot = i % slots
        tab = jnp.asarray(pad_block_table(blocks, nbt), jnp.int32)
        pc = paged_cache_join(cfg, pc, src, slot, tab)
        taken = paged_cache_take(cfg, pc, slot)
        _assert_roundtrip(cfg, src, taken)
        # free + clear: the next iteration reuses these very pages
        pc = paged_cache_clear_slot(pc, slot)
        pool.free(blocks)
        pool.check()
    assert pool.free_count == pool.num_blocks - 1


# ---------------------------------------------------------------------------
# 3. Null-block isolation
# ---------------------------------------------------------------------------

def test_inactive_rows_cannot_perturb_live_rows(paged_setup):
    """Rows with an empty block table (inactive slots) scatter into the
    null block every step; a co-resident live row's generation must be
    bit-identical to running alone."""
    import jax.numpy as jnp
    from repro.models import (
        init_paged_cache, paged_cache_join, paged_decode_step,
    )

    cfg, params, prefill = paged_setup
    rng = random.Random(7)
    ids = [rng.randrange(cfg.vocab_size) for _ in range(21)]
    t0, src = prefill(ids)

    def run(slots):
        pool = BlockPool(8, BS)
        pc = init_paged_cache(cfg, slots, 8, MAX_LEN, BS)
        tab = jnp.asarray(
            pad_block_table(pool.alloc(pool.blocks_for(21 + 4)),
                            MAX_LEN // BS), jnp.int32)
        pc = paged_cache_join(cfg, pc, src, 0, tab)
        toks, nxt = [t0], [t0] + [9] * (slots - 1)   # garbage in dead rows
        for _ in range(4):
            lg, pc = paged_decode_step(
                cfg, params, jnp.asarray([[t] for t in nxt], jnp.int32), pc)
            t = int(jnp.argmax(lg[0]))
            toks.append(t)
            nxt[0] = t
        return toks

    assert run(1) == run(5)
