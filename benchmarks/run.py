"""Benchmark driver — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME]

Prints human-readable tables plus ``name,us_per_call,derived`` CSV lines
(collected at the end under == CSV ==).
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import List

BENCHES = [
    ("queueing_theory", "§3.2 T/2 vs T/2N"),
    ("ttft_vs_load", "Fig 6a/6b TTFT vs load"),
    ("chunk_util", "Table 1 chunk utilization"),
    ("decode_balance", "Fig 7/8 decode balance"),
    ("cache_aware", "§4.2.2 cache-aware PBAA"),
    ("e2e_pd", "E2E 3P1D pipeline w/ KV transfer"),
    ("cross_arch", "SBS across architecture families"),
    ("microbench", "scheduler decision latency"),
    ("roofline", "§Roofline dry-run table"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    csv: List[str] = ["name,us_per_call,derived"]
    for mod_name, desc in BENCHES:
        if args.only and args.only != mod_name:
            continue
        mod = __import__(f"benchmarks.{mod_name}", fromlist=["main"])
        print(f"\n{'='*72}\n== {mod_name}: {desc}\n{'='*72}", flush=True)
        t0 = time.time()
        try:
            rows = mod.main(lambda s: print(s, flush=True))
            csv.extend(rows or [])
        except Exception as e:
            print(f"BENCH FAILED: {e!r}")
            csv.append(f"{mod_name},NaN,FAILED")
        print(f"[{mod_name} took {time.time()-t0:.1f}s]")
    print(f"\n{'='*72}\n== CSV ==\n{'='*72}")
    for line in csv:
        print(line)


if __name__ == "__main__":
    main()
