"""Benchmark driver — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--quick] [--json]

Prints human-readable tables plus ``name,us_per_call,derived`` CSV lines
(collected at the end under == CSV ==).  ``--json`` additionally writes
``BENCH_e2e.json`` (TTFT p50/p99 + throughput per scheduler per scenario
from the e2e_pd bench) so the perf trajectory is machine-trackable across
PRs; ``--quick`` asks benches that support it for a reduced sweep (the CI
smoke path).
"""
from __future__ import annotations

import argparse
import inspect
import json
import os
import sys
import time
from typing import List

BENCHES = [
    ("queueing_theory", "§3.2 T/2 vs T/2N"),
    ("ttft_vs_load", "Fig 6a/6b TTFT vs load"),
    ("chunk_util", "Table 1 chunk utilization"),
    ("decode_balance", "Fig 7/8 decode balance"),
    ("cache_aware", "§4.2.2 cache-aware PBAA"),
    ("e2e_pd", "E2E 3P1D pipeline w/ KV transfer"),
    ("cross_arch", "SBS across architecture families"),
    ("microbench", "scheduler decision latency"),
    ("roofline", "§Roofline dry-run table"),
]

JSON_PATH = "BENCH_e2e.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweeps for benches that support it")
    ap.add_argument("--json", action="store_true",
                    help=f"write {JSON_PATH} with the e2e_pd payload")
    args = ap.parse_args()

    csv: List[str] = ["name,us_per_call,derived"]
    payload = None
    for mod_name, desc in BENCHES:
        if args.only and args.only != mod_name:
            continue
        mod = __import__(f"benchmarks.{mod_name}", fromlist=["main"])
        print(f"\n{'='*72}\n== {mod_name}: {desc}\n{'='*72}", flush=True)
        t0 = time.time()
        kwargs = {}
        if "quick" in inspect.signature(mod.main).parameters:
            kwargs["quick"] = args.quick
        try:
            rows = mod.main(lambda s: print(s, flush=True), **kwargs)
            csv.extend(rows or [])
            if getattr(mod, "JSON_PAYLOAD", None) is not None:
                payload = mod.JSON_PAYLOAD
        except Exception as e:
            print(f"BENCH FAILED: {e!r}")
            csv.append(f"{mod_name},NaN,FAILED")
        print(f"[{mod_name} took {time.time()-t0:.1f}s]")
    if args.json:
        if payload is None:
            print(f"--json: no payload collected (run the e2e_pd bench)",
                  file=sys.stderr)
            sys.exit(1)
        # merge over the existing file: sections owned by other writers
        # (e.g. the real-plane smoke's `real_plane`) survive a sim rerun
        merged = {}
        if os.path.exists(JSON_PATH):
            try:
                with open(JSON_PATH) as f:
                    merged = json.load(f)
            except (OSError, ValueError):
                merged = {}
        merged.update(payload)
        with open(JSON_PATH, "w") as f:
            json.dump(merged, f, indent=2, sort_keys=True)
        print(f"\nwrote {os.path.abspath(JSON_PATH)}")
    print(f"\n{'='*72}\n== CSV ==\n{'='*72}")
    for line in csv:
        print(line)


if __name__ == "__main__":
    main()
