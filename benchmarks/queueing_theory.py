"""§3.2 theory check — expected wait: immediate T/2 vs staggered T/(2N)."""
from __future__ import annotations

import random
from typing import List


def simulate(n_inst: int, T: float = 1.0, n: int = 50_000, seed: int = 0):
    rng = random.Random(seed)
    arrivals = [rng.uniform(0, 1000.0) for _ in range(n)]
    phases = [rng.uniform(0, T) for _ in range(n_inst)]
    w_imm = [(phases[i % n_inst] - t) % T for i, t in enumerate(arrivals)]
    w_stag = [min((k * T / n_inst - t) % T for k in range(n_inst))
              for t in arrivals]
    return (sum(w_imm) / n, sum(w_stag) / n)


def main(report) -> List[str]:
    rows = []
    report("## §3.2 queueing theory: E[wait] immediate vs staggered (T=1)")
    report(f"{'N':>4} {'immediate':>10} {'theory T/2':>10} "
           f"{'staggered':>10} {'theory T/2N':>11} {'speedup':>8}")
    for n in (2, 4, 8, 16, 32):
        wi, ws = simulate(n)
        rows.append(f"queueing_theory/N={n},{ws*1e6:.0f},"
                    f"speedup={wi/ws:.1f}x")
        report(f"{n:>4} {wi:>10.4f} {0.5:>10.4f} {ws:>10.4f} "
               f"{0.5/n:>11.4f} {wi/ws:>7.1f}x")
    return rows
