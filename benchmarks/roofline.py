"""§Roofline — aggregate the dry-run JSONs into the per-(arch × mesh) table.

Reads experiments/dryrun/*.json produced by repro.launch.dryrun. If the
directory is missing the benchmark reports a pointer instead of failing
(the dry-run needs 512 forced host devices — its own process)."""
from __future__ import annotations

import json
import os
from typing import List

DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_records(path: str = DIR):
    recs = []
    if not os.path.isdir(path):
        return recs
    for f in sorted(os.listdir(path)):
        if f.endswith(".json"):
            with open(os.path.join(path, f)) as fh:
                recs.append(json.load(fh))
    return recs


OPT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun_opt")


def _table(report, rows, recs, tag):
    report(f"\n## Roofline [{tag}] per (arch × shape × mesh), per-device "
           "seconds (v5e: 197 TF/s, 819 GB/s, 50 GB/s ICI)")
    report(f"{'arch':>22} {'shape':>12} {'mesh':>8} {'compute':>9} "
           f"{'memory':>9} {'collective':>10} {'bound':>7} {'useful':>7}")
    ok = fail = skip = 0
    for r in recs:
        if r.get("status") == "skipped":
            skip += 1
            continue
        if r.get("status") != "ok":
            fail += 1
            report(f"{r['arch']:>22} {r['shape']:>12} {r['mesh']:>8} FAILED "
                   f"{r.get('error', '')[:60]}")
            continue
        ok += 1
        rf = r["roofline"]
        report(f"{r['arch']:>22} {r['shape']:>12} {r['mesh']:>8} "
               f"{rf['compute_s']:>9.4f} {rf['memory_s']:>9.4f} "
               f"{rf['collective_s']:>10.4f} "
               f"{rf['bottleneck'].split('_')[0]:>7} "
               f"{rf['useful_ratio']:>7.2f}")
        rows.append(
            f"roofline[{tag}]/{r['arch']}/{r['shape']}/{r['mesh']},"
            f"{max(rf['compute_s'], rf['memory_s'], rf['collective_s'])*1e6:.0f},"
            f"bound={rf['bottleneck'].split('_')[0]}")
    report(f"\n[{tag}] {ok} ok, {skip} skipped (documented), {fail} failed")


def main(report) -> List[str]:
    rows: List[str] = []
    recs = load_records()
    if not recs:
        report("\n## Roofline: no dry-run records found — run "
               "`PYTHONPATH=src python -m repro.launch.dryrun` first")
        return rows
    _table(report, rows, recs, "baseline")
    opt = load_records(OPT_DIR)
    if opt:
        _table(report, rows, opt, "optimized")
    return rows
