"""Table 1 — Prefill chunk utilization and max sustainable QPS, batch
scheduling Off vs On, at a fixed mean-TTFT constraint — plus the
length-bucketed batch-formation A/B: padding FLOPs wasted per dispatched
batch, bucketed vs unbucketed, on heavy-tail prompt lengths."""
from __future__ import annotations

from typing import List

from benchmarks.common import (
    ARCH, find_peak_qps, prefill_serving_cfg, run_prefill,
)
from repro.serving.workload import HEAVY_TAIL, SHORT


def _bucketed_padding(report) -> List[str]:
    """BucketServe-style formation inside the SBS window: one padded-
    length class dispatches per cycle instead of the whole buffer, so
    co-batched prompts pad to near-equal lengths.  Heavy-tail lengths
    (lognormal sigma=1.6) make the unbucketed pad-to-batch-max waste
    large; the column prices it in prefill FLOPs per dispatched batch."""
    from repro.config import get_arch
    from repro.serving.cluster import PrefillClusterSim
    from repro.serving.costmodel import CostModel
    from repro.serving.workload import generate

    rows: List[str] = []
    cfg = get_arch(ARCH)
    cost = CostModel(cfg)
    qps, dur = 25.0, 12.0
    report("\n## Bucketed batch formation (sbs, heavy_tail, "
           f"qps={qps:.0f}): padding FLOPs wasted per batch")
    report(f"{'formation':>12} {'batches':>8} {'pad tok/batch':>14} "
           f"{'pad TFLOPs/batch':>17} {'TTFT':>8}")
    out = {}
    for label, bs in (("unbucketed", 0), ("bucketed", 512)):
        scfg = prefill_serving_cfg(chunk=3072, bucket_size=bs)
        reqs = generate(HEAVY_TAIL, qps=qps, duration=dur, seed=9)
        sim = PrefillClusterSim(cfg, scfg, scheduler="sbs")
        rep = sim.run(reqs, dur)
        batches = max(sim.sched.cycles, 1)
        pad_tok = sim.sched.padding_tokens_wasted / batches
        pad_tf = cost.prefill_flops(
            sim.sched.padding_tokens_wasted) / batches / 1e12
        out[label] = {"pad_tok": pad_tok, "pad_tf": pad_tf,
                      "ttft": rep.ttft_mean}
        report(f"{label:>12} {batches:>8d} {pad_tok:>14.0f} "
               f"{pad_tf:>17.1f} {rep.ttft_mean*1000:>6.0f}ms")
        rows.append(f"chunk_util/bucketed/{label},"
                    f"pad_tok_per_batch={pad_tok:.0f},"
                    f"pad_tflops_per_batch={pad_tf:.1f}")
    if out["unbucketed"]["pad_tok"] > 0:
        d = 1 - out["bucketed"]["pad_tok"] / out["unbucketed"]["pad_tok"]
        report(f"{'':>12} bucketed padding waste vs unbucketed: "
               f"{-d*100:+.1f}%")
    return rows


def main(report) -> List[str]:
    rows: List[str] = []
    report("\n## Table 1: chunk utilization + max QPS @ mean-TTFT constraint")
    report(f"{'scenario':>22} {'batch':>6} {'QPS':>5} {'chunk util':>11} "
           f"{'ΔQPS':>7} {'Δutil':>7}")
    for chunk, slo in ((3072, 0.8), (5120, 1.0)):
        scfg = prefill_serving_cfg(chunk=chunk)
        base = {}
        for sched, name in (("immediate-rr", "Off"), ("sbs", "On")):
            peak = find_peak_qps(sched, slo, SHORT, scfg)
            rep = run_prefill(sched, peak, 15.0, SHORT, scfg)
            if name == "Off":
                base = {"qps": peak, "util": rep.chunk_util}
                dq = du = ""
            else:
                dq = f"+{(peak/base['qps']-1)*100:.1f}%"
                du = f"+{(rep.chunk_util-base['util'])*100:.1f}pp"
            report(f"{'Chunk %dK (TTFT=%.1fs)' % (chunk//1024, slo):>22} "
                   f"{name:>6} {peak:>5.0f} {rep.chunk_util*100:>10.1f}% "
                   f"{dq:>7} {du:>7}")
            rows.append(f"chunk_util/{chunk}/{name},{peak:.0f},"
                        f"util={rep.chunk_util*100:.1f}%")
    rows.extend(_bucketed_padding(report))
    return rows
