"""Table 1 — Prefill chunk utilization and max sustainable QPS, batch
scheduling Off vs On, at a fixed mean-TTFT constraint."""
from __future__ import annotations

from typing import List

from benchmarks.common import find_peak_qps, prefill_serving_cfg, run_prefill
from repro.serving.workload import SHORT


def main(report) -> List[str]:
    rows: List[str] = []
    report("\n## Table 1: chunk utilization + max QPS @ mean-TTFT constraint")
    report(f"{'scenario':>22} {'batch':>6} {'QPS':>5} {'chunk util':>11} "
           f"{'ΔQPS':>7} {'Δutil':>7}")
    for chunk, slo in ((3072, 0.8), (5120, 1.0)):
        scfg = prefill_serving_cfg(chunk=chunk)
        base = {}
        for sched, name in (("immediate-rr", "Off"), ("sbs", "On")):
            peak = find_peak_qps(sched, slo, SHORT, scfg)
            rep = run_prefill(sched, peak, 15.0, SHORT, scfg)
            if name == "Off":
                base = {"qps": peak, "util": rep.chunk_util}
                dq = du = ""
            else:
                dq = f"+{(peak/base['qps']-1)*100:.1f}%"
                du = f"+{(rep.chunk_util-base['util'])*100:.1f}pp"
            report(f"{'Chunk %dK (TTFT=%.1fs)' % (chunk//1024, slo):>22} "
                   f"{name:>6} {peak:>5.0f} {rep.chunk_util*100:>10.1f}% "
                   f"{dq:>7} {du:>7}")
            rows.append(f"chunk_util/{chunk}/{name},{peak:.0f},"
                        f"util={rep.chunk_util*100:.1f}%")
    return rows
