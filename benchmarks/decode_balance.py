"""Figures 7 & 8 — decode KV-load balance and throughput: IQR-aware
lexicographical scheduling vs immediate baselines (closed-loop, avg batch
≈35 per DP unit as in §5.2.2)."""
from __future__ import annotations

from typing import List

from repro.config import ServingConfig, get_arch
from repro.serving.cluster import DecodeClusterSim
from repro.serving.workload import WorkloadSpec, generate

from benchmarks.common import ARCH


def main(report) -> List[str]:
    rows: List[str] = []
    scfg = ServingConfig(num_decode_instances=1, decode_dp_per_instance=32,
                         max_batch_per_dp=64, kv_budget_tokens=200_000)
    spec = WorkloadSpec("decode", 256, 32768, 2000.0, out_mean=500,
                        sigma=1.3)   # heavy-tailed conversational lengths (Fig 7)
    N = 32 * 35
    cfg = get_arch(ARCH)
    report("\n## Fig 7/8: decode balance (DP=32, closed-loop batch≈35/DP)")
    report(f"{'scheduler':>22} {'thr tok/s':>10} {'kv ±1σ band':>18} "
           f"{'band width':>11} {'kv peak':>9} {'batch σ':>8}")
    base_thr = base_band = None
    for sched, pol, name in (
            ("immediate", "round_robin", "baseline (rr)"),
            ("immediate", "least_batch", "least-batch"),
            ("immediate", "least_kv", "least-kv"),
            ("sbs", "round_robin", "SBS (IQR-lex)")):
        reqs = generate(spec, qps=10_000, duration=10, seed=1)[:30_000]
        sim = DecodeClusterSim(cfg, scfg, scheduler=sched, policy=pol)
        rep = sim.run(reqs, 60, closed_loop=N)
        band = rep.kv_band[1] - rep.kv_band[0]
        if name.startswith("baseline"):
            base_thr, base_band = rep.throughput, band
        report(f"{name:>22} {rep.throughput:>10.0f} "
               f"({rep.kv_band[0]:>6.0f},{rep.kv_band[1]:>6.0f}) "
               f"{band:>11.0f} {rep.kv_peak:>9.0f} "
               f"{rep.batch_std_mean:>8.2f}")
        rows.append(f"decode/{name.replace(' ', '_')},"
                    f"{rep.throughput:.0f},band={band:.0f}")
    report(f"SBS vs baseline: throughput {100*(rep.throughput/base_thr-1):+.1f}%, "
           f"±1σ band {100*(band/base_band-1):+.1f}%")
    return rows
