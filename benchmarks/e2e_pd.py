"""End-to-end P/D-disaggregated pipeline (3P1D): SBS on both phases vs
immediate dispatch — TTFT, TPOT, throughput and goodput including the KV
transfer — under three traffic scenarios: steady Poisson, bursty (MMPP
flash crowds), and long-context heavy-tail.

Besides the human-readable table, the run leaves its results in
``JSON_PAYLOAD`` (scenario -> qps -> scheduler -> metrics); the driver's
``--json`` flag serialises it to ``BENCH_e2e.json`` for cross-PR perf
tracking.  ``quick=True`` (CI smoke) shrinks the sweep to one load point
and a shorter horizon per scenario.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.config import ServingConfig, get_arch
from repro.serving.e2e import PDClusterSim
from repro.serving.workload import DECODE_BURST, WorkloadSpec, generate

from benchmarks.common import ARCH

STEADY = WorkloadSpec("e2e", 64, 3000, 1000.0, out_mean=120)
BURSTY = WorkloadSpec("e2e-bursty", 64, 3000, 1000.0, out_mean=120,
                      burst_factor=3.0, burst_duty=0.25, burst_period=2.0)
HEAVY = WorkloadSpec("e2e-heavy", 64, 32768, 2000.0, out_mean=120,
                     sigma=1.6)
# multi-tenant Zipf system prompts — the prefix-cache scenario: the
# cache-aware sim pipeline credits hit prefixes against chunk capacity
# and prices the skipped FLOPs (prefill_flops_saved in the report)
SHARED = WorkloadSpec("e2e-shared", 256, 3000, 1000.0, out_mean=120,
                      n_tenants=24, tenant_zipf=1.2, tenant_prefix_len=384)

SCENARIOS = (
    ("steady", STEADY, (40, 70)),
    ("bursty", BURSTY, (40, 70)),
    ("heavy_tail", HEAVY, (20, 35)),
    ("shared_prefix", SHARED, (40, 70)),
    # decode-heavy MMPP bursts (serving.workload.DECODE_BURST): long
    # generations keep the decode pool saturated while prompt bursts
    # arrive on top — the ITL-sensitive regime the unified mixed-batch
    # plane targets (see _mixed_batch for the piggyback A/B)
    ("decode_burst", DECODE_BURST, (10, 18)),
)

JSON_PAYLOAD: Optional[Dict] = None

# paged-KV concurrency comparison (decode pool at a fixed KV budget)
PC_BLOCK = 512                 # production-ish page: 512 tokens
PC_MAXLEN = 4096               # padded plane's per-slot reservation


def _paged_concurrency(report, quick: bool) -> Dict:
    """Decode-pool KV economics at a fixed per-DP budget under three
    cache accountings: padded max_len slots (every request reserves
    PC_MAXLEN-granular pages), paged blocks (PC_BLOCK granularity), and
    ideal token-granular.  Reports the sustainable concurrency per DP
    (budget / mean per-request reservation — the admission headroom the
    sbs-la allocator sees) and the simulated throughput at equal load
    (the cost model prices decode sweeps on kv_occupancy, so padding is
    paid for, not hidden)."""
    from repro.serving.cluster import DecodeClusterSim

    cfg = get_arch(ARCH)
    budget = 40_000
    spec = WorkloadSpec("paged", 64, 3000, 1000.0, out_mean=120)
    n = 100 if quick else 300

    def fresh_reqs():
        # fresh Request objects per mode: the sim mutates them in place
        return generate(spec, qps=2000, duration=1, seed=5)[:n]

    def reservation(r, block):
        from repro.core.types import blocks_for_tokens
        total = r.input_len + r.output_len
        if not block:
            return total
        return blocks_for_tokens(total, block) * block

    out: Dict = {}
    report("\n### paged KV concurrency (decode pool, equal KV budget "
           f"{budget} tok/DP)")
    report(f"{'accounting':>14} {'mean_resv':>10} {'conc/DP':>8} "
           f"{'throughput':>11}")
    for label, block in (("padded_maxlen", PC_MAXLEN), ("paged", PC_BLOCK),
                         ("ideal", 0)):
        reqs = fresh_reqs()
        mean_resv = sum(reservation(r, block) for r in reqs) / len(reqs)
        conc = budget / mean_resv
        scfg = ServingConfig(num_decode_instances=1,
                             decode_dp_per_instance=8,
                             max_batch_per_dp=256,
                             kv_budget_tokens=budget, block_size=block,
                             decode_slots_per_dp=256 if block else 0)
        sim = DecodeClusterSim(cfg, scfg, scheduler="sbs-la")
        rep = sim.run(reqs, 2 if quick else 5, closed_loop=64)
        out[label] = {"block": block, "mean_reservation": mean_resv,
                      "concurrency_per_dp": conc,
                      "throughput": rep.throughput}
        report(f"{label:>14} {mean_resv:>10.0f} {conc:>8.1f} "
               f"{rep.throughput:>9.0f}/s")
    gain = (out["paged"]["concurrency_per_dp"]
            / out["padded_maxlen"]["concurrency_per_dp"] - 1)
    report(f"{'':>14} paged vs padded concurrency: {gain*100:+.1f}%")
    return out


def _overload_control(report, quick: bool) -> Dict:
    """SLO-aware overload control A/B (sbs-la, equal KV memory): the same
    spike/diurnal traffic with priority classes through (a) the plain
    pipeline ('baseline' — stalled work only moves via watchdog drain),
    (b) page-level preemption, (c) preemption + arrival flow control.
    Goodput (SLO-attained fraction, per-class deadlines) is the headline:
    shedding or swapping batch work must buy interactive goodput, not
    just shuffle load."""
    from repro.serving.workload import SPECS

    cfg = get_arch(ARCH)
    # a deliberately tight decode pool: the spike must actually exhaust
    # KV budgets, otherwise there is nothing to control
    scfg = ServingConfig(num_prefill_instances=2, prefill_dp_per_instance=4,
                         num_decode_instances=2, decode_dp_per_instance=4,
                         chunk_size=3072, t_default=0.5,
                         max_batch_per_dp=16, kv_budget_tokens=12_000)
    duration = 6 if quick else 15
    qps = 24
    out: Dict = {}
    report("\n### SLO-aware overload control (sbs-la, equal KV budget "
           f"{scfg.kv_budget_tokens} tok/DP)")
    for scen in ("overload_spike", "diurnal"):
        spec = SPECS[scen]
        report(f"#### scenario: {scen} (qps={qps})")
        out[scen] = {}
        for mode, kw in (
                ("baseline", {}),
                ("preempt", dict(preemption=True)),
                ("preempt_flow", dict(preemption=True, flow_control=True))):
            reqs = generate(spec, qps=qps, duration=duration, seed=23)
            sim = PDClusterSim(cfg, dataclasses.replace(scfg, **kw),
                               scheduler="sbs-la")
            rep = sim.run(reqs, duration)
            out[scen][mode] = rep.json_row()
            report(f"{mode:>13}  {rep.row()}")
        gain = (out[scen]["preempt"]["goodput"]
                - out[scen]["baseline"]["goodput"])
        report(f"{'':>13}  preempt vs baseline goodput: {gain*100:+.1f}pp")
    return out


def _mixed_reqs(seed: int = 0) -> List:
    """Loaded-pool mixed-batch traffic: 40 long-output chat residents
    keep every decode DP populated, then periodic bursts of long prompts
    land on top.  Each burst's prefill MUST coexist with live decode
    rows (no empty DP absorbs it) — the regime where a disjoint
    prefill/decode loop bubbles the resident rows' ITL and piggybacking
    does not.  Deliberately hand-built: a Poisson stream at sustainable
    qps barely prefills between decode steps, so stall events stay below
    the 1% that an ITL p99 can see."""
    import random

    from repro.core.types import Request

    rng = random.Random(seed)
    reqs: List[Request] = []
    rid = 0
    for i in range(30):           # residents: short prompt, long output
        reqs.append(Request(
            rid=rid, arrival_time=i * 0.005,
            input_len=rng.randrange(200, 800),
            output_len=rng.randrange(300, 600)))
        rid += 1
    for b in range(4):            # bursts: long prompts, short output
        t0 = 0.8 + b * 0.7
        for i in range(12):
            reqs.append(Request(
                rid=rid, arrival_time=t0 + i * 0.002,
                input_len=rng.randrange(2000, 6000),
                output_len=rng.randrange(20, 60)))
            rid += 1
    return reqs


def _mixed_batch(report, quick: bool) -> Dict:
    """Unified mixed-batch plane A/B (sbs-la): the SAME unified
    deployment with chunked prefill piggybacked into the decode steps vs
    the disjoint (prefill-prioritizing) ablation where every prefill
    chunk stalls the resident decode rows.  The pool is deliberately
    loaded (see `_mixed_reqs`) so burst prefill always lands on DPs with
    live decodes — the headline is ITL p99 at equal-or-higher
    throughput.

    Runs on the 7B arch, not ARCH: the mixed chunk must be small
    relative to the decode step time for piggybacking to pay (a chunk
    whose prefill dwarfs the step inflates EVERY resident's ITL to the
    mixed-step time — the Sarathi chunk-sizing tradeoff), and 2048 @ 7B
    sits in the paying regime while 671B would need a per-arch chunk
    sweep that belongs in chunk_util, not here."""
    cfg = get_arch("deepseek-7b")
    # a 4-DP pool: small enough that load-aware placement cannot absorb
    # a burst's prefill on empty DPs (which would make both legs
    # identical — stalls need grants and rows on the SAME DP)
    scfg = ServingConfig(num_prefill_instances=1, num_decode_instances=1,
                         decode_dp_per_instance=4,
                         mixed_batch=True, mixed_chunk=2048,
                         bucket_size=512)
    duration = 4.0
    out: Dict = {}
    report("\n### unified mixed-batch plane (loaded decode pool, sbs-la)")
    for label, piggy in (("piggyback", True), ("disjoint", False)):
        reqs = _mixed_reqs(seed=0)
        sim = PDClusterSim(
            cfg, dataclasses.replace(scfg, mixed_piggyback=piggy),
            scheduler="sbs-la")
        rep = sim.run(reqs, duration)
        row = rep.json_row()
        row["forced_grants"] = sum(i.forced_grants for i in sim.decode)
        row["prefill_tokens"] = sum(i.prefill_tokens for i in sim.decode)
        out[label] = row
        report(f"{label:>12}  {rep.row()}")
    if out["disjoint"]["itl_p99"] > 0:
        gain = 1 - out["piggyback"]["itl_p99"] / out["disjoint"]["itl_p99"]
        report(f"{'':>12}  piggyback ITL p99 vs disjoint: {-gain*100:+.1f}%")
    return out


def main(report, quick: bool = False) -> List[str]:
    global JSON_PAYLOAD
    rows: List[str] = []
    payload: Dict = {}
    cfg = get_arch(ARCH)
    scfg = ServingConfig(num_prefill_instances=3, prefill_dp_per_instance=8,
                         num_decode_instances=1, decode_dp_per_instance=32,
                         chunk_size=3072, t_default=0.5,
                         max_batch_per_dp=64, kv_budget_tokens=400_000)
    duration = 5 if quick else 15
    report("\n## E2E 3P1D pipeline (prefill pool → KV transfer → decode pool)")
    for scen, spec, qpss in SCENARIOS:
        if quick:
            qpss = qpss[:1]
        report(f"### scenario: {scen}")
        report(f"{'scheduler':>12} {'qps':>5}  result")
        payload[scen] = {}
        tenanted = spec.n_tenants > 0
        run_scfg = (dataclasses.replace(scfg, cache_aware=True)
                    if tenanted else scfg)
        for qps in qpss:
            ttft = {}
            payload[scen][str(qps)] = {}
            for sched in ("immediate", "sbs", "sbs-la"):
                reqs = generate(spec, qps=qps, duration=duration, seed=11,
                                with_tokens=tenanted)
                sim = PDClusterSim(cfg, run_scfg, scheduler=sched)
                rep = sim.run(reqs, duration, slo_e2e=15.0)
                ttft[sched] = rep.ttft_mean
                payload[scen][str(qps)][sched] = rep.json_row()
                report(f"{sched:>12} {qps:>5}  {rep.row()}")
                rows.append(f"e2e/{scen}/{sched}/qps={qps},"
                            f"{rep.ttft_mean*1e6:.0f},"
                            f"goodput={rep.goodput*100:.1f}%")
            gain = 1 - ttft["sbs"] / ttft["immediate"]
            report(f"{'':>12} SBS TTFT vs immediate: {gain*100:+.1f}%")
    pc = _paged_concurrency(report, quick)
    payload["paged_concurrency"] = pc
    rows.append(f"e2e/paged_concurrency,"
                f"{pc['paged']['concurrency_per_dp']:.1f},"
                f"padded={pc['padded_maxlen']['concurrency_per_dp']:.1f}")
    oc = _overload_control(report, quick)
    payload["overload"] = oc
    for scen, modes in oc.items():
        rows.append(
            f"e2e/overload/{scen},"
            f"goodput_base={modes['baseline']['goodput']*100:.1f}%,"
            f"goodput_preempt={modes['preempt']['goodput']*100:.1f}%")
    mb = _mixed_batch(report, quick)
    payload["mixed_batch"] = mb
    rows.append(
        f"e2e/mixed_batch/decode_burst,"
        f"itl_p99_piggyback={mb['piggyback']['itl_p99']*1000:.1f}ms,"
        f"itl_p99_disjoint={mb['disjoint']['itl_p99']*1000:.1f}ms")
    # namespace by sweep mode: --quick (duration 5, first qps) and full
    # (duration 15, all qps) numbers are systematically different, so
    # they live under separate keys — a quick rerun can never overwrite
    # full-sweep history, and the ci.sh regression guard only ever
    # compares like with like (path-wise intersection)
    JSON_PAYLOAD = {"e2e_quick" if quick else "e2e_full": payload}
    return rows
