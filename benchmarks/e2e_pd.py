"""End-to-end P/D-disaggregated pipeline (3P1D): SBS on both phases vs
immediate dispatch — TTFT, TPOT, throughput and goodput including the KV
transfer — under three traffic scenarios: steady Poisson, bursty (MMPP
flash crowds), and long-context heavy-tail.

Besides the human-readable table, the run leaves its results in
``JSON_PAYLOAD`` (scenario -> qps -> scheduler -> metrics); the driver's
``--json`` flag serialises it to ``BENCH_e2e.json`` for cross-PR perf
tracking.  ``quick=True`` (CI smoke) shrinks the sweep to one load point
and a shorter horizon per scenario.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.config import ServingConfig, get_arch
from repro.serving.e2e import PDClusterSim
from repro.serving.workload import WorkloadSpec, generate

from benchmarks.common import ARCH

STEADY = WorkloadSpec("e2e", 64, 3000, 1000.0, out_mean=120)
BURSTY = WorkloadSpec("e2e-bursty", 64, 3000, 1000.0, out_mean=120,
                      burst_factor=3.0, burst_duty=0.25, burst_period=2.0)
HEAVY = WorkloadSpec("e2e-heavy", 64, 32768, 2000.0, out_mean=120,
                     sigma=1.6)

SCENARIOS = (
    ("steady", STEADY, (40, 70)),
    ("bursty", BURSTY, (40, 70)),
    ("heavy_tail", HEAVY, (20, 35)),
)

JSON_PAYLOAD: Optional[Dict] = None


def main(report, quick: bool = False) -> List[str]:
    global JSON_PAYLOAD
    rows: List[str] = []
    payload: Dict = {}
    cfg = get_arch(ARCH)
    scfg = ServingConfig(num_prefill_instances=3, prefill_dp_per_instance=8,
                         num_decode_instances=1, decode_dp_per_instance=32,
                         chunk_size=3072, t_default=0.5,
                         max_batch_per_dp=64, kv_budget_tokens=400_000)
    duration = 5 if quick else 15
    report("\n## E2E 3P1D pipeline (prefill pool → KV transfer → decode pool)")
    for scen, spec, qpss in SCENARIOS:
        if quick:
            qpss = qpss[:1]
        report(f"### scenario: {scen}")
        report(f"{'scheduler':>12} {'qps':>5}  result")
        payload[scen] = {}
        for qps in qpss:
            ttft = {}
            payload[scen][str(qps)] = {}
            for sched in ("immediate", "sbs", "sbs-la"):
                reqs = generate(spec, qps=qps, duration=duration, seed=11)
                sim = PDClusterSim(cfg, scfg, scheduler=sched)
                rep = sim.run(reqs, duration, slo_e2e=15.0)
                ttft[sched] = rep.ttft_mean
                payload[scen][str(qps)][sched] = rep.json_row()
                report(f"{sched:>12} {qps:>5}  {rep.row()}")
                rows.append(f"e2e/{scen}/{sched}/qps={qps},"
                            f"{rep.ttft_mean*1e6:.0f},"
                            f"goodput={rep.goodput*100:.1f}%")
            gain = 1 - ttft["sbs"] / ttft["immediate"]
            report(f"{'':>12} SBS TTFT vs immediate: {gain*100:+.1f}%")
    JSON_PAYLOAD = payload
    return rows
