"""End-to-end P/D-disaggregated pipeline (3P1D): SBS on both phases vs
immediate dispatch — TTFT, TPOT, and goodput including the KV transfer."""
from __future__ import annotations

from typing import List

from repro.config import ServingConfig, get_arch
from repro.serving.e2e import PDClusterSim
from repro.serving.workload import WorkloadSpec, generate

from benchmarks.common import ARCH


def main(report) -> List[str]:
    rows: List[str] = []
    cfg = get_arch(ARCH)
    scfg = ServingConfig(num_prefill_instances=3, prefill_dp_per_instance=8,
                         num_decode_instances=1, decode_dp_per_instance=32,
                         chunk_size=3072, t_default=0.5,
                         max_batch_per_dp=64, kv_budget_tokens=400_000)
    spec = WorkloadSpec("e2e", 64, 3000, 1000.0, out_mean=120)
    report("\n## E2E 3P1D pipeline (prefill pool → KV transfer → decode pool)")
    report(f"{'scheduler':>12} {'qps':>5}  result")
    for qps in (40, 70):
        for sched in ("immediate", "sbs"):
            reqs = generate(spec, qps=qps, duration=15, seed=11)
            sim = PDClusterSim(cfg, scfg, scheduler=sched)
            rep = sim.run(reqs, 15, slo_e2e=15.0)
            report(f"{sched:>12} {qps:>5}  {rep.row()}")
            rows.append(f"e2e/{sched}/qps={qps},{rep.ttft_mean*1e6:.0f},"
                        f"goodput={rep.goodput*100:.1f}%")
    return rows
