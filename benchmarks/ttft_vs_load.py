"""Figure 6 — TTFT vs load, SBS vs immediate dispatch.

6a: input 0–3K (mean ~1K), chunk 3K.   6b: input 3K–64K (mean ~6.7K),
chunk 16K. Protocol follows §5.1: find the BASELINE's peak QPS at the TTFT
SLO, then compare both systems at 40–100% of that load.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import find_peak_qps, prefill_serving_cfg, run_prefill
from repro.serving.workload import LONG, SHORT


def _figure(report, rows, spec, chunk, slo, tag):
    scfg = prefill_serving_cfg(chunk=chunk)
    peak = find_peak_qps("immediate-rr", slo, spec, scfg)
    report(f"\n## Fig 6{tag}: workload={spec.name} chunk={chunk} "
           f"baseline peak QPS @ SLO({slo*1000:.0f}ms) = {peak:.0f}")
    report(f"{'load':>5} {'qps':>6} {'imm TTFT':>10} {'SBS TTFT':>10} "
           f"{'ΔTTFT':>7} {'imm devq':>9} {'SBS devq':>9}")
    for frac in (0.4, 0.6, 0.8, 1.0):
        qps = peak * frac
        imm = run_prefill("immediate-rr", qps, 12.0, spec, scfg)
        sbs = run_prefill("sbs", qps, 12.0, spec, scfg)
        d = 1 - sbs.ttft_mean / imm.ttft_mean
        report(f"{frac*100:>4.0f}% {qps:>6.0f} "
               f"{imm.ttft_mean*1000:>9.1f}ms {sbs.ttft_mean*1000:>9.1f}ms "
               f"{d*100:>6.1f}% {imm.device_queue_mean*1000:>8.1f}ms "
               f"{sbs.device_queue_mean*1000:>8.1f}ms")
        rows.append(f"ttft_6{tag}/load={frac:.1f},"
                    f"{sbs.ttft_mean*1e6:.0f},delta={d*100:.1f}%")
    return rows


def main(report) -> List[str]:
    rows: List[str] = []
    _figure(report, rows, SHORT, 3072, 0.9, "a")
    _figure(report, rows, LONG, 16384, 4.0, "b")
    return rows
