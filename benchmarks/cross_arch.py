"""SBS generality: the scheduler's prefill win across architecture families
(dense MHA / MLA / MoE / hybrid / SSM) — each with its own roofline-derived
cost model. The mechanism (HOL-queue relocation + water-filling) is
engine-agnostic, so the TTFT gain should persist while absolute pass times
vary by orders of magnitude."""
from __future__ import annotations

from typing import List

from repro.config import get_arch
from repro.serving.cluster import PrefillClusterSim
from repro.serving.costmodel import CostModel
from repro.serving.workload import SHORT, generate

from benchmarks.common import prefill_serving_cfg

ARCHS = ["deepseek-7b", "minicpm3-4b", "deepseek-v3-671b",
         "jamba-v0.1-52b", "mamba2-370m"]


def main(report) -> List[str]:
    rows: List[str] = []
    report("\n## SBS across architecture families (chunk 3K, 70% load)")
    report(f"{'arch':>20} {'imm TTFT':>10} {'SBS TTFT':>10} {'ΔTTFT':>7} "
           f"{'imm util':>9} {'SBS util':>9}")
    for arch in ARCHS:
        cfg = get_arch(arch)
        cost = CostModel(cfg)
        scfg = prefill_serving_cfg()
        # scale load to each arch's capacity: ~70% of one-chunk-per-pass rate
        pass_t = cost.prefill_pass_time([scfg.chunk_size], scfg.chunk_size)
        cap_qps = (scfg.num_prefill_instances * scfg.chunk_size
                   / pass_t / 1000.0)
        qps = 0.7 * cap_qps
        res = {}
        for sched in ("immediate-rr", "sbs"):
            reqs = generate(SHORT, qps=qps, duration=12, seed=5)
            sim = PrefillClusterSim(cfg, scfg, scheduler=sched, cost=cost)
            res[sched] = sim.run(reqs, 12)
        i, s = res["immediate-rr"], res["sbs"]
        d = 1 - s.ttft_mean / i.ttft_mean
        report(f"{arch:>20} {i.ttft_mean*1000:>9.1f}ms "
               f"{s.ttft_mean*1000:>9.1f}ms {d*100:>6.1f}% "
               f"{i.chunk_util*100:>8.1f}% {s.chunk_util*100:>8.1f}%")
        rows.append(f"cross_arch/{arch},{s.ttft_mean*1e6:.0f},"
                    f"delta={d*100:.1f}%")
    return rows
