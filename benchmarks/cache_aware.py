"""§4.2.2 'Optimization for Context Caching' — cache-aware PBAA routes
requests to the DP retaining their prefix KV (radix-tree index), cutting
redundant prefill compute on shared-prefix workloads (dialogue/RAG)."""
from __future__ import annotations

from typing import List

from repro.config import get_arch
from repro.serving.cluster import PrefillClusterSim
from repro.serving.workload import SHORT, generate

from benchmarks.common import ARCH, prefill_serving_cfg


def main(report) -> List[str]:
    rows: List[str] = []
    report("\n## §4.2.2 cache-aware PBAA (70% shared-prefix workload)")
    report(f"{'mode':>14} {'TTFT':>9} {'tokens processed':>17} "
           f"{'compute saved':>14}")
    base_tokens = None
    for aware, name in ((False, "basic"), (True, "cache-aware")):
        scfg = prefill_serving_cfg(cache_aware=aware)
        reqs = generate(SHORT, qps=60, duration=12, seed=9,
                        with_tokens=True, shared_prefix_prob=0.7)
        sim = PrefillClusterSim(get_arch(ARCH), scfg, scheduler="sbs")
        rep = sim.run(reqs, 12)
        toks = sum(i.tokens_processed for i in sim.instances)
        if base_tokens is None:
            base_tokens = toks
            saved = ""
        else:
            saved = f"-{100*(1-toks/base_tokens):.1f}%"
        report(f"{name:>14} {rep.ttft_mean*1000:>8.1f}ms {toks:>17d} "
               f"{saved:>14}")
        rows.append(f"cache_aware/{name},{rep.ttft_mean*1e6:.0f},"
                    f"tokens={toks}")
    return rows
