"""Shared helpers for the paper-reproduction benchmarks."""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

from repro.config import ServingConfig, get_arch
from repro.serving.cluster import PrefillClusterSim
from repro.serving.workload import SPECS, WorkloadSpec, generate

ARCH = "deepseek-v3-671b"            # the paper's production model


def prefill_serving_cfg(chunk: int = 3072, instances: int = 3,
                        dp: int = 8, **kw) -> ServingConfig:
    # T_default comes from "offline stress testing" (paper §4.1.1) — here,
    # the roofline cost model priced at a full chunk pass.
    from repro.serving.costmodel import CostModel
    t_default = CostModel(get_arch(ARCH)).prefill_dp_time(chunk)
    base = dict(num_prefill_instances=instances, prefill_dp_per_instance=dp,
                chunk_size=chunk, t_default=t_default)
    base.update(kw)
    return ServingConfig(**base)


def run_prefill(scheduler: str, qps: float, duration: float,
                spec: WorkloadSpec, scfg: ServingConfig, seed: int = 0):
    cfg = get_arch(ARCH)
    reqs = generate(spec, qps=qps, duration=duration, seed=seed)
    sim = PrefillClusterSim(cfg, scfg, scheduler=scheduler)
    return sim.run(reqs, duration)


def find_peak_qps(scheduler: str, slo_ttft: float, spec: WorkloadSpec,
                  scfg: ServingConfig, duration: float = 12.0,
                  lo: float = 10.0, hi: float = 400.0, iters: int = 8
                  ) -> float:
    """Binary-search the max QPS whose mean TTFT meets the SLO (paper §5.1
    'benchmark the baseline to determine its peak QPS')."""
    for _ in range(iters):
        mid = (lo + hi) / 2
        rep = run_prefill(scheduler, mid, duration, spec, scfg)
        if rep.ttft_mean <= slo_ttft and rep.rejected == 0:
            lo = mid
        else:
            hi = mid
    return lo


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0)
