"""Scheduler-core micro-benchmarks: allocation-algorithm costs at production
batch sizes (the scheduler must tick every I_opt ≈ 10-80 ms; its own
decision latency has to be orders of magnitude below that)."""
from __future__ import annotations

import random
import time
from typing import List

from repro.core.decode_alloc import schedule_decode_batch
from repro.core.prefill_alloc import pbaa
from repro.core.types import DecodeDPState, DPState, Request


def _time(fn, reps=20):
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6   # µs


def main(report) -> List[str]:
    rows: List[str] = []
    rng = random.Random(0)
    report("\n## Scheduler micro-benchmarks (decision latency)")
    report(f"{'op':>34} {'us/call':>10}")

    def bench_pbaa():
        dps = [DPState(i, 0, 16384) for i in range(8)]
        reqs = [Request(rid=i, arrival_time=0,
                        input_len=rng.randrange(100, 8000))
                for i in range(64)]
        pbaa([], reqs, dps)
    us = _time(bench_pbaa)
    report(f"{'PBAA (64 reqs × 8 DPs)':>34} {us:>10.1f}")
    rows.append(f"micro/pbaa_64x8,{us:.1f},")

    def bench_decode():
        units = [DecodeDPState(i, 0, batch=rng.randrange(40),
                               kv_tokens=rng.randrange(100_000))
                 for i in range(32)]
        reqs = [Request(rid=i, arrival_time=0,
                        input_len=rng.randrange(100, 8000))
                for i in range(64)]
        schedule_decode_batch(reqs, units)
    us = _time(bench_decode)
    report(f"{'IQR-lex decode (64 reqs × 32 DPs)':>34} {us:>10.1f}")
    rows.append(f"micro/decode_64x32,{us:.1f},")

    from repro.core.prefix_cache import RadixTree
    t = RadixTree(block=16)
    seqs = [tuple(rng.randrange(1000) for _ in range(512)) for _ in range(64)]
    for s in seqs[:32]:
        t.insert(s)

    def bench_radix():
        for s in seqs:
            t.match(s)
    us = _time(bench_radix) / 64
    report(f"{'radix match (512 tokens)':>34} {us:>10.1f}")
    rows.append(f"micro/radix_match_512,{us:.1f},")

    # BlockPool free store: heapq (current) vs the sorted-list it
    # replaced.  Both are deterministic lowest-id-first; the access
    # pattern that matters is serving churn — small per-request
    # alloc/free against a LARGE mostly-free pool, where the list
    # re-sorts the whole store on every free (O(N log N)) and copies it
    # on every alloc (O(N)), while the heap pays O(req log N).
    from repro.serving.kv_pool import BlockPool

    N_BLOCKS, REQ_BLOCKS, ROUNDS = 65536, 8, 256

    class _SortedListStore:
        """The pre-heap free store, inlined for comparison."""
        def __init__(self, num_blocks):
            self.free = list(range(1, num_blocks))
        def alloc(self, n):
            out, self.free = self.free[:n], self.free[n:]
            return out
        def free_blocks(self, ids):
            self.free = sorted(self.free + list(ids))

    def _churn(alloc, free):
        crng = random.Random(42)
        held = []
        for _ in range(ROUNDS):
            held.append(alloc(REQ_BLOCKS))
            if len(held) > 64:
                free(held.pop(crng.randrange(len(held))))

    pool = BlockPool(N_BLOCKS, 16)
    store = _SortedListStore(N_BLOCKS)
    for name, fn in (
            ("pool_heap", lambda: _churn(pool.alloc, pool.free)),
            ("pool_sorted", lambda: _churn(store.alloc,
                                           store.free_blocks))):
        us = _time(fn, reps=5)
        report(f"{f'{name} churn (8-blk reqs, 64K pool)':>34} {us:>10.1f}")
        rows.append(f"micro/{name}_churn_64k,{us:.1f},")
    return rows
