"""Scheduler-core micro-benchmarks: allocation-algorithm costs at production
batch sizes (the scheduler must tick every I_opt ≈ 10-80 ms; its own
decision latency has to be orders of magnitude below that), plus the
sharded-plane collective probes (EP all-to-all and the merged cross-DP
decode step at 2/4/8 forced host devices) that calibrate
``CostModel.with_measured_sync``."""
from __future__ import annotations

import os
import random
import subprocess
import sys
import time
from typing import List

from repro.core.decode_alloc import schedule_decode_batch
from repro.core.prefill_alloc import pbaa
from repro.core.types import DecodeDPState, DPState, Request


def _time(fn, reps=20):
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6   # µs


# Run in a SUBPROCESS per device count: the forced host-platform device
# count must be pinned before jax initializes, and this process (like the
# rest of the bench suite) stays on the normal 1-device platform.
_SHARDED_PROBE = r'''
import time

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config.base import get_arch
from repro.launch.mesh import make_engine_mesh
from repro.models.model import init_params
from repro.serving.real_engine import EngineSpec

NDEV = %(ndev)d
mesh = make_engine_mesh(NDEV)
cfg = get_arch("granite-moe-1b-a400m", reduced=True)


def _t(fn, reps):
    fn()                                    # warm (compile)
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


# Raw EP all-to-all round trip, sized like one MoE layer's activation
# exchange: top_k * d_model bf16 per token, 64 tokens per DP rank,
# dispatch + combine.
buf = jax.device_put(
    jnp.zeros((NDEV * 64, cfg.moe.top_k * cfg.d_model), jnp.bfloat16),
    NamedSharding(mesh, P("data", None)))


def _xchg(x):
    y = jax.lax.all_to_all(x, "data", 0, 0, tiled=True)      # dispatch
    return jax.lax.all_to_all(y, "data", 0, 0, tiled=True)   # combine


a2a = jax.jit(shard_map(_xchg, mesh=mesh, in_specs=P("data", None),
                        out_specs=P("data", None)))
print("ep_a2a %%.1f" %% _t(lambda: jax.block_until_ready(a2a(buf)), 20))

# Full merged cross-DP decode step (one mesh program over the whole
# instance-wide paged cache; _LockedJit blocks until ready for us).
params = init_params(cfg, jax.random.PRNGKey(0))
spec = EngineSpec(cfg, params, max_len=64, max_batch=2, block_size=8,
                  mesh=mesh)
cache = spec.merged_paged_cache()
toks = jnp.zeros((cache["cur"].shape[0], 1), jnp.int32)
print("decode_step %%.1f"
      %% _t(lambda: spec.jit_paged_decode(spec.params, toks, cache), 10))
'''


def _sharded_rows(report) -> List[str]:
    rows: List[str] = []
    report("\n## Sharded-plane collectives (subprocess per device count)")
    report(f"{'op':>34} {'us/call':>10}")
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for ndev in (2, 4, 8):
        env = {**os.environ, "PYTHONPATH": "src",
               "XLA_FLAGS": (f"--xla_force_host_platform_device_count={ndev} "
                             + os.environ.get("XLA_FLAGS", ""))}
        out = subprocess.run(
            [sys.executable, "-c", _SHARDED_PROBE % {"ndev": ndev}],
            capture_output=True, text=True, timeout=600, env=env, cwd=root)
        if out.returncode != 0:
            report(f"  {ndev}-device probe FAILED: "
                   + out.stderr.strip()[-400:])
            rows.append(f"micro/ep_a2a_{ndev}dev,NaN,FAILED")
            rows.append(f"micro/sharded_decode_step_{ndev}dev,NaN,FAILED")
            continue
        vals = dict(line.split() for line in out.stdout.splitlines()
                    if line.strip())
        for key, name in (("ep_a2a", f"ep_a2a_{ndev}dev"),
                          ("decode_step",
                           f"sharded_decode_step_{ndev}dev")):
            us = float(vals[key])
            report(f"{name:>34} {us:>10.1f}")
            rows.append(f"micro/{name},{us:.1f},")
    return rows


def main(report) -> List[str]:
    rows: List[str] = []
    rng = random.Random(0)
    report("\n## Scheduler micro-benchmarks (decision latency)")
    report(f"{'op':>34} {'us/call':>10}")

    def bench_pbaa():
        dps = [DPState(i, 0, 16384) for i in range(8)]
        reqs = [Request(rid=i, arrival_time=0,
                        input_len=rng.randrange(100, 8000))
                for i in range(64)]
        pbaa([], reqs, dps)
    us = _time(bench_pbaa)
    report(f"{'PBAA (64 reqs × 8 DPs)':>34} {us:>10.1f}")
    rows.append(f"micro/pbaa_64x8,{us:.1f},")

    def bench_decode():
        units = [DecodeDPState(i, 0, batch=rng.randrange(40),
                               kv_tokens=rng.randrange(100_000))
                 for i in range(32)]
        reqs = [Request(rid=i, arrival_time=0,
                        input_len=rng.randrange(100, 8000))
                for i in range(64)]
        schedule_decode_batch(reqs, units)
    us = _time(bench_decode)
    report(f"{'IQR-lex decode (64 reqs × 32 DPs)':>34} {us:>10.1f}")
    rows.append(f"micro/decode_64x32,{us:.1f},")

    from repro.core.prefix_cache import RadixTree
    t = RadixTree(block=16)
    seqs = [tuple(rng.randrange(1000) for _ in range(512)) for _ in range(64)]
    for s in seqs[:32]:
        t.insert(s)

    def bench_radix():
        for s in seqs:
            t.match(s)
    us = _time(bench_radix) / 64
    report(f"{'radix match (512 tokens)':>34} {us:>10.1f}")
    rows.append(f"micro/radix_match_512,{us:.1f},")

    # BlockPool free store: heapq (current) vs the sorted-list it
    # replaced.  Both are deterministic lowest-id-first; the access
    # pattern that matters is serving churn — small per-request
    # alloc/free against a LARGE mostly-free pool, where the list
    # re-sorts the whole store on every free (O(N log N)) and copies it
    # on every alloc (O(N)), while the heap pays O(req log N).
    from repro.serving.kv_pool import BlockPool

    N_BLOCKS, REQ_BLOCKS, ROUNDS = 65536, 8, 256

    class _SortedListStore:
        """The pre-heap free store, inlined for comparison."""
        def __init__(self, num_blocks):
            self.free = list(range(1, num_blocks))
        def alloc(self, n):
            out, self.free = self.free[:n], self.free[n:]
            return out
        def free_blocks(self, ids):
            self.free = sorted(self.free + list(ids))

    def _churn(alloc, free):
        crng = random.Random(42)
        held = []
        for _ in range(ROUNDS):
            held.append(alloc(REQ_BLOCKS))
            if len(held) > 64:
                free(held.pop(crng.randrange(len(held))))

    pool = BlockPool(N_BLOCKS, 16)
    store = _SortedListStore(N_BLOCKS)
    for name, fn in (
            ("pool_heap", lambda: _churn(pool.alloc, pool.free)),
            ("pool_sorted", lambda: _churn(store.alloc,
                                           store.free_blocks))):
        us = _time(fn, reps=5)
        report(f"{f'{name} churn (8-blk reqs, 64K pool)':>34} {us:>10.1f}")
        rows.append(f"micro/{name}_churn_64k,{us:.1f},")

    rows.extend(_sharded_rows(report))
    return rows
