"""Quickstart: the three layers of the framework in one script.

1. SBS scheduler core (Algorithms 1–3) on synthetic state — no JAX needed.
2. A reduced model: prefill → chunked prefill → decode, all consistent.
3. A 60-second cluster simulation: SBS vs immediate dispatch.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

# --- 1. the scheduler core ---------------------------------------------
from repro.core import (
    AdaptiveIntervalController, DecodeDPState, DPState, Request,
    pbaa, schedule_decode_batch,
)

print("== 1. SBS core ==")
ic = AdaptiveIntervalController(window_size=8, l_net=0.002, t_default=0.25,
                                n_active=3)
for t in (0.21, 0.24, 0.19):
    ic.on_end_forward(t)
print(f"adaptive interval I_opt = {ic.interval*1000:.1f} ms "
      f"(T̄={ic.t_fwd:.3f}s / N=3)")

dps = [DPState(dp_id=i, instance_id=0, c_chunk=3072) for i in range(4)]
reqs = [Request(rid=i, arrival_time=0.0, input_len=l)
        for i, l in enumerate([2800, 1900, 1200, 700, 400])]
assign, pending, _ = pbaa([], reqs, dps)
print("PBAA water-filling:",
      {d: sum(t for _, t in lst) for d, lst in assign.items()},
      f"carry-over={len(pending)}")

units = [DecodeDPState(dp_id=i, instance_id=0, batch=b, kv_tokens=k)
         for i, (b, k) in enumerate([(30, 80_000), (32, 60_000),
                                     (31, 70_000), (35, 400_000)])]
out = schedule_decode_batch(
    [Request(rid=9, arrival_time=0, input_len=5000)], units)
print(f"IQR-lex decode placed the request on DP {list(out)[0]} "
      "(the 400k-KV straggler was masked)")

# --- 2. a real (reduced) model ------------------------------------------
print("\n== 2. reduced deepseek-v3 model: prefill → chunk → decode ==")
from repro.config import get_arch
from repro.models import decode_step, init_params, prefill
from repro.models.model import prefill_chunk

cfg = get_arch("deepseek-v3-671b", reduced=True)
params = init_params(cfg, jax.random.PRNGKey(0))
tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 24), 0,
                            cfg.vocab_size)
logits, cache = prefill(cfg, params, tokens[:, :8], max_len=64)
logits, cache = prefill_chunk(cfg, params, tokens[:, 8:24], cache)
nxt = jnp.argmax(logits, -1)[:, None]
for _ in range(4):
    logits, cache = decode_step(cfg, params, nxt, cache)
    nxt = jnp.argmax(logits, -1)[:, None]
    print("generated token:", int(nxt[0, 0]))

# --- 3. cluster simulation ------------------------------------------------
print("\n== 3. cluster sim: SBS vs immediate (10s, 50 qps) ==")
from repro.config import ServingConfig
from repro.serving.cluster import PrefillClusterSim
from repro.serving.workload import SHORT, generate

scfg = ServingConfig(num_prefill_instances=3, prefill_dp_per_instance=8,
                     chunk_size=3072, t_default=0.1)
full_cfg = get_arch("deepseek-v3-671b")
for sched in ("immediate-rr", "sbs"):
    rs = generate(SHORT, qps=50, duration=10, seed=0)
    rep = PrefillClusterSim(full_cfg, scfg, scheduler=sched).run(rs, 10)
    print(f"{sched:13s} {rep.row()}")
