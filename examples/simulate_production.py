"""Reproduce the paper's production-scale experiments in the discrete-event
simulator: the 3P1D DeepSeek-V3 cluster (§5) — TTFT vs load, chunk
utilization, and decode balance — plus the bursty and long-context
heavy-tail traffic scenarios on the unified ClusterRuntime.

    PYTHONPATH=src python examples/simulate_production.py [--quick]
"""
import argparse
import dataclasses

from repro.config import ServingConfig, get_arch
from repro.serving.cluster import DecodeClusterSim, PrefillClusterSim
from repro.serving.e2e import PDClusterSim
from repro.serving.workload import (
    BURSTY, DECODE_BURST, DIURNAL, HEAVY_TAIL, OVERLOAD_SPIKE,
    SHARED_PREFIX, SHORT, WorkloadSpec, generate,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    dur = 8.0 if args.quick else 20.0

    cfg = get_arch("deepseek-v3-671b")
    print("== Prefill: 3 instances × DP8, chunk 3K, DeepSeek-V3 ==")
    scfg = ServingConfig(num_prefill_instances=3, prefill_dp_per_instance=8,
                         chunk_size=3072, t_default=0.1)
    for qps in (60, 100, 130):
        line = [f"qps={qps:4d}"]
        for sched in ("immediate-rr", "sbs"):
            reqs = generate(SHORT, qps=qps, duration=dur, seed=0)
            rep = PrefillClusterSim(cfg, scfg, scheduler=sched).run(reqs, dur)
            line.append(f"{sched}: ttft={rep.ttft_mean*1000:6.1f}ms "
                        f"util={rep.chunk_util*100:4.1f}%")
        print("   ".join(line))

    print("\n== Prefill scenarios: bursty (MMPP) & long-context heavy-tail ==")
    for name, spec, qps in (("bursty", BURSTY, 80),
                            ("heavy_tail", HEAVY_TAIL, 25)):
        line = [f"{name:>10} qps={qps:3d}"]
        for sched in ("immediate-rr", "sbs"):
            reqs = generate(spec, qps=qps, duration=dur, seed=7)
            rep = PrefillClusterSim(cfg, scfg, scheduler=sched).run(reqs, dur)
            line.append(f"{sched}: ttft={rep.ttft_mean*1000:7.1f}ms "
                        f"p99={rep.ttft_p99*1000:7.1f}ms")
        print("   ".join(line))

    print("\n== Prefill: shared_prefix (Zipf multi-tenant system prompts) ==")
    for label, c in (("sbs", scfg),
                     ("sbs+cache", dataclasses.replace(scfg,
                                                       cache_aware=True))):
        reqs = generate(SHARED_PREFIX, qps=100, duration=dur, seed=3,
                        with_tokens=True)
        sim = PrefillClusterSim(cfg, c, scheduler="sbs")
        rep = sim.run(reqs, dur)
        cache = getattr(sim.sched, "cache", None)
        hr = cache.hit_rate if cache is not None else 0.0
        print(f"{label:>10} ttft={rep.ttft_mean*1000:7.1f}ms "
              f"p99={rep.ttft_p99*1000:7.1f}ms "
              f"util={rep.chunk_util*100:4.1f}% hit={hr*100:4.1f}%")

    print("\n== Decode: DP=32, EP=32, closed-loop batch ≈ 35/DP ==")
    dcfg = ServingConfig(num_decode_instances=1, decode_dp_per_instance=32,
                         max_batch_per_dp=64, kv_budget_tokens=200_000)
    spec = WorkloadSpec("decode", 256, 32768, 2000.0, out_mean=500)
    for sched in ("immediate", "sbs", "sbs-la"):
        reqs = generate(spec, qps=10_000, duration=5, seed=1)[:15_000]
        sim = DecodeClusterSim(cfg, dcfg, scheduler=sched)
        rep = sim.run(reqs, 30.0 if args.quick else 60.0,
                      closed_loop=32 * 35)
        print(f"{sched:10s} {rep.row()}")

    print("\n== Decode-heavy bursts (decode_burst): P/D pipeline vs "
          "unified mixed-batch plane ==")
    # same 4-DP decode pool on the 7B arch (mixed chunk sizing is
    # per-arch; 2048 @ 7B keeps the mixed step near the decode step —
    # see benchmarks/e2e_pd._mixed_batch): the unified plane runs
    # chunked prefill inside the decode steps, no transfer hop
    cfg7 = get_arch("deepseek-7b")
    mdur = 4.0 if args.quick else 8.0
    pipe_cfg = ServingConfig(num_prefill_instances=1,
                             prefill_dp_per_instance=4,
                             num_decode_instances=1,
                             decode_dp_per_instance=4, chunk_size=2048)
    unified_cfg = ServingConfig(num_prefill_instances=1,
                                num_decode_instances=1,
                                decode_dp_per_instance=4,
                                mixed_batch=True, mixed_chunk=2048,
                                bucket_size=512)
    for label, c in (("pd_pipeline", pipe_cfg),
                     ("unified", unified_cfg),
                     ("unified_disjoint", dataclasses.replace(
                         unified_cfg, mixed_piggyback=False))):
        reqs = generate(DECODE_BURST, qps=6, duration=mdur, seed=31)
        sim = PDClusterSim(cfg7, c, scheduler="sbs-la")
        rep = sim.run(reqs, mdur)
        print(f"{label:>17}  {rep.row()}")

    print("\n== Overload control: SLO classes under a 5x spike and a "
          "compressed diurnal cycle ==")
    # a deliberately tight decode pool (2x4 DP, 12K KV tokens each): the
    # spike exhausts the KV budgets, so preemption/flow-control have real
    # choices; goodput buckets by class deadline (see core.types)
    ocfg = ServingConfig(num_prefill_instances=2, prefill_dp_per_instance=4,
                         num_decode_instances=2, decode_dp_per_instance=4,
                         chunk_size=3072, t_default=0.5,
                         max_batch_per_dp=16, kv_budget_tokens=12_000)
    odur = 6.0 if args.quick else 15.0
    for scen, spec in (("overload_spike", OVERLOAD_SPIKE),
                       ("diurnal", DIURNAL)):
        print(f"-- {scen} (qps=24, sbs-la)")
        for mode, kw in (("baseline", {}),
                         ("preempt", dict(preemption=True)),
                         ("preempt+flow", dict(preemption=True,
                                               flow_control=True))):
            reqs = generate(spec, qps=24, duration=odur, seed=23)
            sim = PDClusterSim(cfg, dataclasses.replace(ocfg, **kw),
                               scheduler="sbs-la")
            rep = sim.run(reqs, odur)
            print(f"{mode:>13}  {rep.row()}")


if __name__ == "__main__":
    main()
