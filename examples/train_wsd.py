"""Train a (reduced) MiniCPM with the WSD schedule on structured synthetic
data for a few hundred steps — the training-side end-to-end driver.

    PYTHONPATH=src python examples/train_wsd.py [--steps 300]
"""
import argparse
import math

from repro.config import TrainConfig, get_arch
from repro.data import synthetic_batches
from repro.train import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--branching", type=int, default=4)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_arch(args.arch, reduced=True)
    tcfg = TrainConfig(global_batch=args.batch, seq_len=args.seq, lr=3e-3,
                       schedule="wsd", warmup_steps=args.steps // 10,
                       total_steps=args.steps)
    print(f"training {cfg.name}: {args.steps} steps of "
          f"{args.batch}×{args.seq} tokens, WSD schedule")
    tr = Trainer(cfg, tcfg, ckpt_dir=args.ckpt)
    batches = synthetic_batches(cfg.vocab_size, args.batch, args.seq,
                                branching=args.branching)
    res = tr.fit(batches, args.steps, log_every=max(args.steps // 15, 1))
    if args.ckpt:
        tr.save()
    print(f"final CE {res['final_ce']:.4f}; optimal "
          f"ln({args.branching}) = {math.log(args.branching):.4f}")


if __name__ == "__main__":
    main()
