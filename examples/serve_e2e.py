"""End-to-end driver: serve a small model through the REAL P/D-separated
SBS control plane — ClusterRuntime in realtime mode drives threaded
engines executing true chunked prefill, KV-cache handoff, and continuous
batched decode on jitted JAX forwards; EndForward feedback adapts the
dispatch interval online.  Runs every scheduler variant over the same
request set and reports per-request TTFT.

    PYTHONPATH=src python examples/serve_e2e.py [--requests 8] [--arch ID]
        [--schedulers immediate,sbs,sbs-la] [--timeout 120]

Exits non-zero if any request fails to finish within the timeout (used
by `scripts/ci.sh --real-smoke`).
"""
import argparse
import random
import sys

import jax

from repro.config import ServingConfig, get_arch
from repro.core.types import Request
from repro.models import init_params
from repro.serving.real_engine import EngineSpec
from repro.serving.server import RealSBSServer


def make_requests(n, cfg, max_new, seed):
    rng = random.Random(seed)
    lens = [rng.randrange(20, 90) for _ in range(n)]
    toks = [tuple(rng.randrange(cfg.vocab_size) for _ in range(L))
            for L in lens]
    # fresh Request objects per serve() call (timing stamps are per-run)
    return lambda: [
        Request(rid=i, arrival_time=i * 0.05, input_len=lens[i],
                output_len=max_new, tokens=toks[i])
        for i in range(n)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--timeout", type=float, default=120.0)
    ap.add_argument("--schedulers", default="immediate,sbs,sbs-la")
    args = ap.parse_args()

    cfg = get_arch(args.arch, reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    fresh = make_requests(args.requests, cfg, args.max_new, args.seed)

    scfg = ServingConfig(num_prefill_instances=2, prefill_dp_per_instance=2,
                         num_decode_instances=1, decode_dp_per_instance=2,
                         chunk_size=32, t_default=0.05, l_net=0.001,
                         max_batch_per_dp=8)
    print(f"serving {args.requests} requests on {cfg.name} "
          f"({scfg.num_prefill_instances}P x {scfg.prefill_dp_per_instance}DP"
          f" -> {scfg.num_decode_instances}D x {scfg.decode_dp_per_instance}DP,"
          f" chunk={scfg.chunk_size})")
    # one shared spec: each jitted chunk/step shape compiles once for the
    # whole scheduler sweep
    spec = EngineSpec(cfg, params, max_len=160,
                      max_batch=scfg.max_batch_per_dp, max_new=args.max_new)
    ok = True
    for sched in args.schedulers.split(","):
        reqs = fresh()
        srv = RealSBSServer(cfg, params, serving_cfg=scfg, scheduler=sched,
                            max_len=160, max_new=args.max_new, spec=spec)
        gens = srv.serve(reqs, timeout=args.timeout)
        print(f"\n== scheduler={sched}: {len(gens)}/{len(reqs)} finished; "
              f"adapted I_opt={srv.state.interval.interval*1000:.1f}ms "
              f"T_fwd={srv.state.interval.t_fwd*1000:.1f}ms")
        for g in gens:
            print(f"  rid={g.rid} ttft={g.ttft*1000:7.1f}ms tokens={g.tokens}")
        if len(gens) < len(reqs):
            missing = sorted(set(r.rid for r in reqs)
                             - set(g.rid for g in gens))
            print(f"  UNFINISHED rids: {missing}")
            ok = False
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
