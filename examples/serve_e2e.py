"""End-to-end driver: serve a small model through the REAL P/D-separated
SBS control plane — ClusterRuntime in realtime mode drives threaded
engines executing true chunked prefill, KV-cache handoff, and continuous
batched decode (paged block-table KV by default) on jitted JAX forwards;
EndForward feedback adapts the dispatch interval online.  Runs every
scheduler variant over the same request set and reports per-request TTFT
plus the decode plane's peak concurrent residency.

    PYTHONPATH=src python examples/serve_e2e.py [--requests 8] [--arch ID]
        [--schedulers immediate,sbs,sbs-la] [--timeout 120]
        [--block-size 16] [--compare-padded] [--bench-json BENCH_e2e.json]

`--compare-padded` re-runs the sweep with padded max_len slots at the
SAME KV memory budget and requires the paged plane to sustain strictly
more concurrent decode requests; `--bench-json` records the comparison
in the bench payload's `real_plane` section.  Exits non-zero if any
request fails to finish within the timeout, or if the paged plane does
not win the comparison (used by `scripts/ci.sh --real-smoke`).

`--prefix-bench` runs the shared-prefix A/B instead: multi-tenant
repeat-heavy traffic served twice at EQUAL KV memory — prefix caching
off, then on (refcounted page sharing + COW).  Requires the cached run
to post a lower TTFT p99 and > 0 prefill FLOPs saved, and records both
sides in the payload's `real_plane_prefix` section.

`--overload-bench` runs the SLO-overload A/B instead: batch-class KV
hogs fill the ENTIRE paged decode pool, then interactive requests with
a tight e2e deadline arrive mid-decode.  The same trace is served twice
at EQUAL KV memory — drain-only (deferred joins wait for residents to
finish) vs page-level preemption (lower-priority residents are swapped
out to host and resumed later).  Requires the preempting run to post
strictly higher goodput (SLO-attained fraction) with every request
still finishing, and records both sides in the payload's
`real_plane_overload` section.

`--mixed-bench` runs the unified mixed-batch A/B instead: long-output
residents decode on a small unified pool while long prompts arrive
mid-decode, served twice — chunked prefill piggybacked into the decode
steps vs the disjoint (prefill-prioritizing, rows stall) ablation.
Requires piggybacking to post a strictly lower ITL p99 at
equal-or-higher throughput, and records both sides in the payload's
`real_plane_mixed` section.

`--sharded-bench` runs the sharded DP+EP A/B instead: the deployment is
MESH-NATIVE (4 decode DP units merged into one cache sharded over a
4-device forced-host mesh, every step a cross-DP program with the
explicit EP all-to-all live), served under immediate dispatch vs SBS
staggered batch formation.  Requires sbs-la to post a strictly lower
TTFT p99 at equal-or-higher throughput, records per-step sync stall and
the measured per-step sync cost that calibrates `CostModel.t_sync`, and
writes the payload's `real_plane_sharded` section.  Use the granite MoE
config (`--arch granite-moe-1b-a400m`) so the expert count divides the
mesh.
"""
import argparse
import json
import os
import random
import sys

# --sharded-bench serves on a 4-device forced-host mesh; the device
# count must be pinned BEFORE the first jax import (the same bootstrap
# launch/dryrun.py uses), so peek at argv here
if ("--sharded-bench" in sys.argv
        and "xla_force_host_platform_device_count"
        not in os.environ.get("XLA_FLAGS", "")):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                               + os.environ.get("XLA_FLAGS", ""))

import jax

from repro.config import ServingConfig, get_arch
from repro.core.types import Request
from repro.models import init_params
from repro.serving.real_engine import EngineSpec
from repro.serving.server import RealSBSServer

MAX_LEN = 160


def make_requests(n, cfg, max_new, seed, spacing):
    rng = random.Random(seed)
    lens = [rng.randrange(20, 90) for _ in range(n)]
    toks = [tuple(rng.randrange(cfg.vocab_size) for _ in range(L))
            for L in lens]
    # fresh Request objects per serve() call (timing stamps are per-run)
    return lambda: [
        Request(rid=i, arrival_time=i * spacing, input_len=lens[i],
                output_len=max_new, tokens=toks[i])
        for i in range(n)]


def run_sweep(label, cfg, params, scfg, fresh, args):
    """One scheduler sweep over one cache backend; returns (ok, peaks)."""
    print(f"\n#### backend={label}: "
          f"{scfg.num_prefill_instances}P x {scfg.prefill_dp_per_instance}DP"
          f" -> {scfg.num_decode_instances}D x {scfg.decode_dp_per_instance}"
          f"DP, chunk={scfg.chunk_size}, "
          + (f"paged block_size={scfg.block_size} "
             f"slots/DP={scfg.resolved_decode_slots}" if scfg.block_size
             else f"padded slots/DP={scfg.max_batch_per_dp}"))
    # one shared spec per backend: each jitted chunk/step shape compiles
    # once for the whole scheduler sweep
    spec = EngineSpec(cfg, params, max_len=MAX_LEN,
                      max_batch=scfg.max_batch_per_dp, max_new=args.max_new,
                      block_size=scfg.block_size,
                      decode_slots=(scfg.resolved_decode_slots
                                    if scfg.block_size else 0))
    ok = True
    peaks = {}
    for sched in args.schedulers.split(","):
        reqs = fresh()
        srv = RealSBSServer(cfg, params, serving_cfg=scfg, scheduler=sched,
                            max_len=MAX_LEN, max_new=args.max_new, spec=spec)
        gens = srv.serve(reqs, timeout=args.timeout)
        peak = max((e.peak_resident for e in srv.decode_engines), default=0)
        peaks[sched] = peak
        print(f"\n== scheduler={sched}: {len(gens)}/{len(reqs)} finished; "
              f"adapted I_opt={srv.state.interval.interval*1000:.1f}ms "
              f"T_fwd={srv.state.interval.t_fwd*1000:.1f}ms "
              f"peak_decode_resident={peak}")
        for g in gens:
            print(f"  rid={g.rid} ttft={g.ttft*1000:7.1f}ms tokens={g.tokens}")
        if len(gens) < len(reqs):
            missing = sorted(set(r.rid for r in reqs)
                             - set(g.rid for g in gens))
            print(f"  UNFINISHED rids: {missing}")
            ok = False
    return ok, peaks


def run_prefix_bench(cfg, params, args):
    """Shared-prefix A/B on the real plane: same tenanted workload, same
    KV memory, prefix caching off vs on.  Returns (ok, report-section).

    One prefill instance (SBS staggers dispatch windows per instance, so
    a single instance makes every repeat resolve against the binder that
    actually holds its pages); three tenant system prompts recycled
    round-robin so most requests after the first wave are block-aligned
    prefix (or exact full-prompt) hits."""
    from repro.serving.costmodel import CostModel
    from repro.serving.metrics import percentile

    bs = args.block_size or 16
    scfg = ServingConfig(
        num_prefill_instances=1, prefill_dp_per_instance=2,
        num_decode_instances=1, decode_dp_per_instance=2,
        chunk_size=32, t_default=0.05, l_net=0.001,
        max_batch_per_dp=args.max_batch_per_dp, block_size=bs)
    rng = random.Random(args.seed)
    # one fixed prompt per tenant: every request after the first wave is
    # an exact repeat of its tenant's prompt — a FULL prefix hit, so the
    # cached plane answers it without running a single prefill chunk
    prompts = [tuple(rng.randrange(cfg.vocab_size)
                     for _ in range(96 + 8 + t)) for t in range(3)]
    order = [i % len(prompts) for i in range(args.requests)]
    # prefill on this plane is seconds per request (CPU wall-clock), so
    # repeats must arrive AFTER their tenant's first prompt completes
    # and publishes its pages — space arrivals accordingly
    spacing = max(args.arrival_spacing, 1.5)

    def fresh():
        return [Request(rid=i, arrival_time=i * spacing,
                        input_len=len(prompts[t]),
                        output_len=args.max_new, tokens=prompts[t])
                for i, t in enumerate(order)]

    warm_toks = tuple(rng.randrange(cfg.vocab_size) for _ in range(100))
    cost = CostModel(cfg)
    spec = EngineSpec(cfg, params, max_len=MAX_LEN,
                      max_batch=args.max_batch_per_dp, max_new=args.max_new,
                      block_size=bs,
                      decode_slots=scfg.resolved_decode_slots)
    print(f"\n#### prefix-cache A/B: {args.requests} requests, 3 tenants "
          f"x 96-token prompts, block_size={bs}")
    ok = True
    section = {"block_size": bs, "requests": args.requests}
    for mode in ("uncached", "cached"):
        srv = RealSBSServer(cfg, params, serving_cfg=scfg, scheduler="sbs",
                            max_len=MAX_LEN, max_new=args.max_new, spec=spec,
                            prefix_cache=(mode == "cached"))
        # warmup compiles every jitted shape outside the timed window
        srv.serve([Request(rid=999, arrival_time=0.0, input_len=100,
                           output_len=args.max_new, tokens=warm_toks)],
                  timeout=args.timeout)
        pre = srv.prefix_stats()
        gens = srv.serve(fresh(), timeout=args.timeout)
        post = srv.prefix_stats()
        if len(gens) < args.requests:
            print(f"  {mode}: UNFINISHED "
                  f"({len(gens)}/{args.requests})")
            ok = False
        ttfts = [g.ttft for g in gens]
        # the first wave (one request per tenant) is cold in BOTH modes
        # by construction; the caching claim is about the steady state,
        # so the headline p99 is over the repeat-eligible requests
        steady = [g.ttft for g in gens if g.rid >= len(prompts)]
        hit = post["prefix_hit_tokens"] - pre["prefix_hit_tokens"]
        seen = post["prefix_seen_tokens"] - pre["prefix_seen_tokens"]
        section[mode] = {
            "ttft_mean": sum(ttfts) / max(len(ttfts), 1),
            "ttft_p99": percentile(steady, 99) if steady else 0.0,
            "ttft_p99_all": percentile(ttfts, 99) if ttfts else 0.0,
            "prefix_hit_rate": hit / seen if seen else 0.0,
            "prefill_flops_saved": cost.prefill_flops(hit),
            "prefill_chunks_run": (post["prefill_chunks_run"]
                                   - pre["prefill_chunks_run"]),
            "decode_blocks_shared": (post["decode_blocks_shared"]
                                     - pre["decode_blocks_shared"]),
        }
        s = section[mode]
        print(f"  {mode:>9}: ttft_p99={s['ttft_p99']*1000:7.1f}ms "
              f"mean={s['ttft_mean']*1000:7.1f}ms "
              f"hit={s['prefix_hit_rate']*100:5.1f}% "
              f"chunks={s['prefill_chunks_run']} "
              f"saved={s['prefill_flops_saved']:.2e} FLOPs")
    if ok:
        c, u = section["cached"], section["uncached"]
        if not (c["prefill_flops_saved"] > 0
                and c["ttft_p99"] < u["ttft_p99"]):
            print("  prefix-cache gate FAILED: need flops_saved > 0 and "
                  "cached ttft_p99 < uncached ttft_p99")
            ok = False
        else:
            print(f"  gate OK: ttft_p99 "
                  f"{(1 - c['ttft_p99'] / u['ttft_p99']) * 100:+.1f}% "
                  f"vs uncached")
    return ok, section


def run_overload_bench(cfg, params, args):
    """SLO-overload A/B on the real plane: same trace, equal KV memory,
    drain-only vs page-level preemption.  Returns (ok, report-section).

    The decode pool is sized so ONE batch-class hog fills a whole DP
    (max_batch_per_dp=1 → 10 blocks of 16 at max_len 160; a 24-in /
    128-out hog reserves exactly 10 blocks for its lifetime).  Six hogs
    in tight waves keep both DPs saturated for several hog generations;
    two interactive requests (priority 0, tight deadline) arrive while
    the first wave is mid-decode, so their joins defer on device
    capacity.  Drain-only: they queue BEHIND the later hog waves (joins
    retry FIFO) and blow the deadline.  Preempting: the runtime swaps a hog's pages to host
    (generation state intact), the interactive request joins
    immediately, and the hogs resume once their blocks free up — every
    request still finishes, but now inside its SLO."""
    import dataclasses

    bs = args.block_size or 16
    scfg = ServingConfig(
        num_prefill_instances=1, prefill_dp_per_instance=2,
        num_decode_instances=1, decode_dp_per_instance=2,
        chunk_size=32, t_default=0.05, l_net=0.001,
        max_batch_per_dp=1, block_size=bs)
    rng = random.Random(args.seed)
    n_hogs = 6
    hog_in, hog_out = 24, MAX_LEN - 24 - 8     # lifetime 152 ≤ max_len 160
    int_in, int_out = 72, 4
    hog_toks = [tuple(rng.randrange(cfg.vocab_size) for _ in range(hog_in))
                for _ in range(n_hogs)]
    int_toks = [tuple(rng.randrange(cfg.vocab_size) for _ in range(int_in))
                for _ in range(2)]

    def fresh():
        hogs = [Request(rid=i, arrival_time=0.01 * i, input_len=hog_in,
                        output_len=hog_out, tokens=hog_toks[i],
                        priority=2, slo_e2e=float(args.timeout),
                        slo_class="batch")
                for i in range(n_hogs)]
        inter = [Request(rid=10 + i, arrival_time=0.15 + 0.03 * i,
                         input_len=int_in, output_len=int_out,
                         tokens=int_toks[i],
                         priority=0, slo_e2e=args.interactive_slo,
                         slo_class="interactive")
                 for i in range(2)]
        return hogs + inter

    spec = EngineSpec(cfg, params, max_len=MAX_LEN,
                      max_batch=scfg.max_batch_per_dp, max_new=hog_out,
                      block_size=bs, decode_slots=scfg.resolved_decode_slots)
    # warmup must compile BOTH paged-join shapes (the jitted join
    # specialises on the block count) or the first timed run pays the
    # compiles and the A/B compares compile time, not scheduling
    warm = [Request(rid=998, arrival_time=0.0, input_len=hog_in,
                    output_len=hog_out, tokens=hog_toks[0]),
            Request(rid=999, arrival_time=0.1, input_len=int_in,
                    output_len=int_out, tokens=int_toks[0])]
    # throwaway compile pass: the very first serve pays every jit
    # compile, and a mode whose own warmup measured compile-bloated wall
    # times would enter the timed run with a hugely inflated adaptive
    # interval — burn the compiles OUTSIDE the A/B so both modes' warmups
    # adapt from warm timings
    RealSBSServer(cfg, params, serving_cfg=scfg, scheduler="sbs-la",
                  max_len=MAX_LEN, max_new=hog_out, spec=spec).serve(
        [dataclasses.replace(r) for r in warm], timeout=args.timeout)
    print(f"\n#### SLO-overload A/B: {n_hogs} batch hogs "
          f"({hog_in}in/{hog_out}out, one fills a DP) + 2 interactive "
          f"({int_in}in/{int_out}out, slo={args.interactive_slo:.1f}s), "
          f"block_size={bs}")
    ok = True
    section = {"block_size": bs, "interactive_slo": args.interactive_slo}
    for mode in ("drain_only", "preempt"):
        srv = RealSBSServer(cfg, params,
                            serving_cfg=dataclasses.replace(
                                scfg, preemption=(mode == "preempt")),
                            scheduler="sbs-la", max_len=MAX_LEN,
                            max_new=hog_out, spec=spec)
        # warmup compiles every jitted shape outside the timed window
        srv.serve([dataclasses.replace(r) for r in warm],
                  timeout=args.timeout)
        reqs = fresh()
        gens = srv.serve(reqs, timeout=args.timeout)
        if len(gens) < len(reqs):
            missing = sorted(set(r.rid for r in reqs)
                             - set(g.rid for g in gens))
            print(f"  {mode}: UNFINISHED rids {missing}")
            ok = False
        inter = [r for r in reqs if r.slo_class == "interactive"]
        attained = [r for r in reqs if r.slo_attained()]
        section[mode] = {
            "goodput": len(attained) / len(reqs),
            "goodput_interactive": (sum(1 for r in inter
                                        if r.slo_attained())
                                    / max(len(inter), 1)),
            "e2e_interactive": [
                (r.finish_time - r.arrival_time
                 if r.finish_time is not None else None) for r in inter],
            "preemptions": len(srv.runtime.preempted),
            "finished": len(gens),
        }
        s = section[mode]
        e2e = ["--" if v is None else f"{v:5.2f}s"
               for v in s["e2e_interactive"]]
        print(f"  {mode:>10}: goodput={s['goodput']*100:5.1f}% "
              f"interactive={s['goodput_interactive']*100:5.1f}% "
              f"e2e_int={e2e} preemptions={s['preemptions']}")
    if ok:
        d, p = section["drain_only"], section["preempt"]
        if not (p["goodput"] > d["goodput"] and p["preemptions"] > 0):
            print("  overload gate FAILED: need preempt goodput strictly "
                  "above drain-only and preemptions > 0")
            ok = False
        else:
            print(f"  gate OK: goodput {d['goodput']*100:.1f}% -> "
                  f"{p['goodput']*100:.1f}% "
                  f"({p['preemptions']} preemptions)")
    return ok, section


def run_mixed_bench(cfg, params, args):
    """Unified mixed-batch A/B on the real plane: the same trace served
    twice by the SAME unified (decode-pool-only) deployment — chunked
    prefill piggybacked into the decode steps vs the disjoint ablation
    (prefill-prioritizing: a step with pending prefill runs only the
    chunk and the resident decode rows stall).  Returns
    (ok, report-section).

    Four long-output residents decode on a 2-DP pool while eight long
    prompts arrive mid-decode; `mixed_chunk` is small enough that each
    prompt needs several chunk-steps, so the disjoint leg inserts
    repeated stall bubbles into the residents' token streams.  Gate:
    piggybacking must post a strictly lower ITL p99 at equal-or-higher
    throughput (tokens / completion wall time).  ITL is the strict
    axis; both legs do identical total work, so their throughputs are
    theoretically near-equal and "equal" is judged with a 5%
    measurement tolerance over the median of three timed serves —
    single wall-clock samples on a shared host swing more than the
    piggyback effect size."""
    import dataclasses

    from repro.serving.metrics import percentile

    bs = args.block_size or 16
    rng = random.Random(args.seed)
    res_in, res_out = 32, 120        # lifetime 152 ≤ max_len 160
    burst_in, burst_out = 96, 4      # lifetime 100; 96 = 3 chunks of 32
    scfg = ServingConfig(
        num_prefill_instances=1, prefill_dp_per_instance=1,
        num_decode_instances=1, decode_dp_per_instance=2,
        chunk_size=32, t_default=0.05, l_net=0.001,
        max_batch_per_dp=8, block_size=bs,
        mixed_batch=True, mixed_chunk=32)
    res_toks = [tuple(rng.randrange(cfg.vocab_size) for _ in range(res_in))
                for _ in range(4)]
    burst_toks = [tuple(rng.randrange(cfg.vocab_size)
                        for _ in range(burst_in)) for _ in range(8)]

    def fresh():
        res = [Request(rid=i, arrival_time=0.02 * i, input_len=res_in,
                       output_len=res_out, tokens=res_toks[i])
               for i in range(4)]
        burst = [Request(rid=10 + i, arrival_time=0.5 + 0.15 * i,
                         input_len=burst_in, output_len=burst_out,
                         tokens=burst_toks[i])
                 for i in range(8)]
        return res + burst

    print(f"\n#### mixed-batch A/B: 4 residents ({res_in}in/{res_out}out) "
          f"+ 8 prompts ({burst_in}in/{burst_out}out) on a 2-DP unified "
          f"pool, mixed_chunk={scfg.mixed_chunk}, block_size={bs}")
    ok = True
    section = {"block_size": bs, "mixed_chunk": scfg.mixed_chunk}
    spec = EngineSpec(cfg, params, max_len=MAX_LEN,
                      max_batch=scfg.max_batch_per_dp,
                      max_new=max(res_out, burst_out),
                      block_size=bs, decode_slots=scfg.resolved_decode_slots)
    for mode, piggy in (("piggyback", True), ("disjoint", False)):
        srv = RealSBSServer(cfg, params,
                            serving_cfg=dataclasses.replace(
                                scfg, mixed_piggyback=piggy),
                            scheduler="sbs-la", max_len=MAX_LEN,
                            max_new=max(res_out, burst_out), spec=spec)
        # warmup serve of the SAME trace: compiles every jitted
        # mixed/prefill/decode shape this leg will hit, so the timed
        # runs measure scheduling, not compilation
        srv.serve(fresh(), timeout=args.timeout)
        # median of three timed serves: single wall-clock runs on a
        # shared host swing tens of percent on transient load, which is
        # exactly what a strict A/B gate must not be judging
        samples = []
        for _ in range(3):
            for e in srv.decode_engines:
                e.itl.clear()
            reqs = fresh()
            gens = srv.serve(reqs, timeout=args.timeout)
            if len(gens) < len(reqs):
                missing = sorted(set(r.rid for r in reqs)
                                 - set(g.rid for g in gens))
                print(f"  {mode}: UNFINISHED rids {missing}")
                ok = False
                break
            itls = [s for e in srv.decode_engines for s in e.itl]
            toks = sum(r.generated for r in reqs)
            span = max((r.finish_time for r in reqs
                        if r.finish_time is not None), default=0.0)
            samples.append({
                "itl_p50": percentile(itls, 50) if itls else 0.0,
                "itl_p99": percentile(itls, 99) if itls else 0.0,
                "throughput": toks / span if span > 0 else 0.0,
            })
        if not samples:
            continue
        med = {k: sorted(s[k] for s in samples)[len(samples) // 2]
               for k in samples[0]}
        section[mode] = med
        section[mode].update({
            "runs": len(samples),
            "mixed_steps": sum(e.mixed_steps for e in srv.decode_engines),
            "forced_grants": sum(e.forced_grants
                                 for e in srv.decode_engines),
            "prefill_tokens": sum(e.prefill_tokens
                                  for e in srv.decode_engines),
        })
        s = section[mode]
        print(f"  {mode:>9}: itl_p99={s['itl_p99']*1000:7.1f}ms "
              f"p50={s['itl_p50']*1000:6.1f}ms thr={s['throughput']:6.1f} "
              f"tok/s mixed_steps={s['mixed_steps']} "
              f"prefill_tok={s['prefill_tokens']}")
    if ok:
        p, d = section["piggyback"], section["disjoint"]
        if not (p["itl_p99"] < d["itl_p99"]
                and p["throughput"] >= 0.95 * d["throughput"]):
            print("  mixed gate FAILED: need piggyback itl_p99 strictly "
                  "below disjoint at equal-or-higher throughput "
                  "(5% tolerance)")
            ok = False
        else:
            print(f"  gate OK: itl_p99 "
                  f"{(1 - p['itl_p99'] / d['itl_p99']) * 100:+.1f}% "
                  f"thr {(p['throughput'] / d['throughput'] - 1) * 100:+.1f}%"
                  f" vs disjoint")
    return ok, section


def _measure_step_sync(spec_sh, spec_lo, reps=20):
    """Per-step DP sync cost, measured: wall time of the merged sharded
    decode step (mesh collectives + EP all-to-all over every DP's rows)
    minus the equivalent single-device per-DP step.  The minimum over
    `reps` filters scheduler noise; the difference is what one cross-DP
    barrier actually charges — the number `CostModel.t_sync` hardcodes
    as 4ms."""
    import time

    import jax.numpy as jnp

    def best(spec, cache):
        toks = jnp.zeros((cache["cur"].shape[0], 1), jnp.int32)
        out = spec.jit_paged_decode(spec.params, toks, cache)  # compile
        jax.block_until_ready(out[0])
        t = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            out = spec.jit_paged_decode(spec.params, toks, cache)
            jax.block_until_ready(out[0])
            t = min(t, time.perf_counter() - t0)
        return t

    t_sh = best(spec_sh, spec_sh.merged_paged_cache())
    t_lo = best(spec_lo, spec_lo.paged_cache())
    return max(t_sh - t_lo, 0.0), t_sh, t_lo


def run_sharded_bench(cfg, params, args):
    """Sharded DP+EP A/B on the real plane: the SAME trace served by a
    mesh-native deployment — 4 decode DP units merged into ONE cache
    sharded over a 4-device (forced host) mesh, every engine step a
    cross-DP program with the explicit EP all-to-all active — under
    immediate dispatch vs SBS staggered batch formation.  Returns
    (ok, report-section).

    A BURST of alternating long (144) and short (16) prompts, so
    immediate-rr's count-based round-robin piles every long prompt on
    one prefill instance while SBS's capacity-argmax batch formation
    balances token load — the cross-DP skew the paper's Load-Aware
    allocation targets.  Immediate's trickled handoffs additionally join
    decode one by one, so the merged plane runs many LOW-OCCUPANCY
    full-mesh steps (each paying the whole collective program for a few
    live rows) that contend with prefill for the one mesh; aligned
    formation joins in waves — visibly lower sync-stall integral and
    higher step occupancy.  Gate: sbs-la must post strictly lower TTFT
    p99 at equal-or-higher throughput (5% tolerance; latencies are
    medians of five timed serves, throughput the best serve — makespan
    noise is one-sided).  The section also records the per-step sync-stall
    integral Σ dur·(1 − active/rows) from the engines' step samples and
    the measured per-step sync cost that calibrates
    `CostModel.t_sync`."""
    import dataclasses

    from repro.launch.mesh import make_engine_mesh
    from repro.serving.costmodel import CostModel
    from repro.serving.metrics import percentile

    n_dp = 4
    if len(jax.devices()) < n_dp:
        print(f"sharded bench needs {n_dp} devices (forced host), have "
              f"{len(jax.devices())} — run via --sharded-bench in a fresh "
              f"process so the XLA_FLAGS bootstrap applies")
        return False, {}
    bs = args.block_size or 16
    mesh = make_engine_mesh(n_dp)
    long_in, short_in, out = 144, 16, 16     # lifetime 160 == max_len
    scfg = ServingConfig(
        num_prefill_instances=2, prefill_dp_per_instance=1,
        num_decode_instances=1, decode_dp_per_instance=n_dp,
        chunk_size=64, t_default=0.02, l_net=0.001,
        max_batch_per_dp=2, block_size=bs,
        # the burst IS the experiment: keep PBAA's overload detection
        # from shedding it (n_limit counts waiting cycles before a
        # request is rejected)
        n_limit=1000)
    rng = random.Random(args.seed)
    lens = [long_in if i % 2 == 0 else short_in for i in range(16)]
    toks = [tuple(rng.randrange(cfg.vocab_size) for _ in range(L))
            for L in lens]
    # the A/B needs a BURST: with arrivals spread wider than a prompt's
    # service time there is no queueing, so formation policy cannot
    # matter and SBS only pays its dispatch-interval wait
    spacing = min(args.arrival_spacing, 0.005)

    def fresh():
        return [Request(rid=i, arrival_time=i * spacing,
                        input_len=lens[i], output_len=out, tokens=toks[i])
                for i in range(len(lens))]

    spec = EngineSpec(cfg, params, max_len=MAX_LEN,
                      max_batch=scfg.max_batch_per_dp, max_new=out,
                      block_size=bs, decode_slots=scfg.resolved_decode_slots,
                      mesh=mesh)
    spec_lo = EngineSpec(cfg, params, max_len=MAX_LEN,
                         max_batch=scfg.max_batch_per_dp, max_new=out,
                         block_size=bs,
                         decode_slots=scfg.resolved_decode_slots)
    # hard evidence the EP shard_map path is live: the compiled merged
    # step must contain the explicit all-to-all
    probe = spec.merged_paged_cache()
    import jax.numpy as jnp
    hlo = spec.jit_paged_decode.lower(
        spec.params, jnp.zeros((probe["cur"].shape[0], 1), jnp.int32),
        probe).compile().as_text()
    ep_active = "all-to-all" in hlo
    t_sync, t_sh, t_lo = _measure_step_sync(spec, spec_lo)
    cost = CostModel(cfg).with_measured_sync(t_sync)
    print(f"\n#### sharded DP+EP A/B: {len(lens)} requests "
          f"({long_in}/{short_in} alternating, {out} out) on a "
          f"{n_dp}-device data mesh, merged decode cache "
          f"{n_dp}x{spec.paged_slots} rows, block_size={bs}, "
          f"EP all-to-all in step HLO: {ep_active}")
    print(f"  measured per-step sync: sharded={t_sh*1000:.2f}ms "
          f"local={t_lo*1000:.2f}ms -> t_sync={t_sync*1000:.2f}ms "
          f"(CostModel default {CostModel(cfg).t_sync*1000:.1f}ms)")
    ok = ep_active
    section = {
        "block_size": bs, "n_dp": n_dp, "requests": len(lens),
        "ep_all_to_all_active": ep_active,
        "t_sync_measured_ms": t_sync * 1000,
        "t_step_sharded_ms": t_sh * 1000,
        "t_step_local_ms": t_lo * 1000,
        "t_sync_calibrated_costmodel_ms": cost.t_sync * 1000,
    }
    for sched in ("immediate", "sbs-la"):
        srv = RealSBSServer(cfg, params, serving_cfg=scfg, scheduler=sched,
                            max_len=MAX_LEN, max_new=out, spec=spec,
                            mesh=mesh)
        # warmup serve of the same trace: burns every jitted shape this
        # leg hits and warm-starts the adaptive interval
        srv.serve(fresh(), timeout=args.timeout)
        samples = []
        for _ in range(5):
            for e in srv.decode_engines:
                e.step_samples.clear()
            reqs = fresh()
            gens = srv.serve(reqs, timeout=args.timeout)
            if len(gens) < len(reqs):
                missing = sorted(set(r.rid for r in reqs)
                                 - set(g.rid for g in gens))
                print(f"  {sched}: UNFINISHED rids {missing}")
                ok = False
                break
            ttfts = [g.ttft for g in gens]
            total = sum(r.generated for r in reqs)
            span = max((r.finish_time for r in reqs
                        if r.finish_time is not None), default=0.0)
            stall = sum(d * (1 - a / r)
                        for e in srv.decode_engines
                        for d, a, r in e.step_samples if r)
            steps = sum(len(e.step_samples) for e in srv.decode_engines)
            occ = (sum(a / r for e in srv.decode_engines
                       for d, a, r in e.step_samples if r)
                   / max(steps, 1))
            samples.append({
                "ttft_p99": percentile(ttfts, 99) if ttfts else 0.0,
                "ttft_mean": sum(ttfts) / max(len(ttfts), 1),
                "throughput": total / span if span > 0 else 0.0,
                "sync_stall_ms": stall * 1000,
                "decode_steps": steps,
                "mean_occupancy": occ,
            })
        if not samples:
            continue
        med = {k: sorted(s[k] for s in samples)[len(samples) // 2]
               for k in samples[0]}
        # throughput = tokens / burst makespan, and the makespan is a
        # MAX over requests — host jitter (GC, CPU contention) can only
        # inflate it, never shrink it, so a serve's throughput is
        # noise-depressed one-sidedly.  The max over serves is the
        # stable estimator of sustained capability; both legs get the
        # same treatment (latency metrics stay medians).
        med["throughput"] = max(s["throughput"] for s in samples)
        med["runs"] = len(samples)
        section[sched] = med
        print(f"  {sched:>9}: ttft_p99={med['ttft_p99']*1000:7.1f}ms "
              f"mean={med['ttft_mean']*1000:7.1f}ms "
              f"thr={med['throughput']:6.1f} tok/s "
              f"stall={med['sync_stall_ms']:7.1f}ms "
              f"steps={med['decode_steps']} "
              f"occ={med['mean_occupancy']*100:5.1f}%")
    if ok and "immediate" in section and "sbs-la" in section:
        i, s = section["immediate"], section["sbs-la"]
        if not (s["ttft_p99"] < i["ttft_p99"]
                and s["throughput"] >= 0.95 * i["throughput"]):
            print("  sharded gate FAILED: need sbs-la ttft_p99 strictly "
                  "below immediate at equal-or-higher throughput "
                  "(5% tolerance)")
            ok = False
        else:
            dstall = ((1 - s["sync_stall_ms"] / i["sync_stall_ms"]) * 100
                      if i["sync_stall_ms"] else 0.0)
            print(f"  gate OK: ttft_p99 "
                  f"{(1 - s['ttft_p99'] / i['ttft_p99']) * 100:+.1f}% "
                  f"thr {(s['throughput'] / i['throughput'] - 1) * 100:+.1f}%"
                  f" stall {dstall:+.1f}% vs immediate")
    elif ok:
        ok = False
    return ok, section


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--timeout", type=float, default=120.0)
    ap.add_argument("--schedulers", default="immediate,sbs,sbs-la")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged-KV block size; 0 = padded max_len slots")
    ap.add_argument("--max-batch-per-dp", type=int, default=8,
                    help="decode KV memory budget per DP, in max_len slots")
    ap.add_argument("--arrival-spacing", type=float, default=0.05)
    ap.add_argument("--compare-padded", action="store_true",
                    help="also run padded slots at equal memory and demand "
                         "strictly higher paged decode concurrency")
    ap.add_argument("--bench-json", default=None,
                    help="record the real-plane comparison into this "
                         "benchmark payload (e.g. BENCH_e2e.json)")
    ap.add_argument("--prefix-bench", action="store_true",
                    help="run the shared-prefix caching A/B (equal KV "
                         "memory, caching off vs on) instead of the "
                         "scheduler sweep")
    ap.add_argument("--overload-bench", action="store_true",
                    help="run the SLO-overload A/B (equal KV memory, "
                         "drain-only vs page-level preemption) instead "
                         "of the scheduler sweep")
    ap.add_argument("--interactive-slo", type=float, default=0.6,
                    help="e2e deadline (s) for the interactive class in "
                         "--overload-bench")
    ap.add_argument("--mixed-bench", action="store_true",
                    help="run the unified mixed-batch A/B (piggybacked "
                         "chunked prefill vs the disjoint stall-the-rows "
                         "ablation) instead of the scheduler sweep")
    ap.add_argument("--sharded-bench", action="store_true",
                    help="run the sharded DP+EP A/B (merged decode cache "
                         "on a 4-device forced-host mesh, EP all-to-all "
                         "live; immediate vs sbs-la) instead of the "
                         "scheduler sweep")
    args = ap.parse_args()
    if args.compare_padded and not args.block_size:
        ap.error("--compare-padded needs a paged plane (--block-size > 0); "
                 "with --block-size 0 the concurrency gate would silently "
                 "not run")

    cfg = get_arch(args.arch, reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))

    if (args.prefix_bench or args.overload_bench or args.mixed_bench
            or args.sharded_bench):
        if args.prefix_bench:
            key, (ok, section) = ("real_plane_prefix",
                                  run_prefix_bench(cfg, params, args))
        elif args.overload_bench:
            key, (ok, section) = ("real_plane_overload",
                                  run_overload_bench(cfg, params, args))
        elif args.sharded_bench:
            key, (ok, section) = ("real_plane_sharded",
                                  run_sharded_bench(cfg, params, args))
        else:
            key, (ok, section) = ("real_plane_mixed",
                                  run_mixed_bench(cfg, params, args))
        if args.bench_json:
            payload = {}
            if os.path.exists(args.bench_json):
                try:
                    with open(args.bench_json) as f:
                        payload = json.load(f)
                except (OSError, ValueError):
                    payload = {}
            payload[key] = section
            with open(args.bench_json, "w") as f:
                json.dump(payload, f, indent=2, sort_keys=True)
            print(f"\nupdated {os.path.abspath(args.bench_json)} "
                  f"[{key}]")
        sys.exit(0 if ok else 1)

    fresh = make_requests(args.requests, cfg, args.max_new, args.seed,
                          args.arrival_spacing)
    print(f"serving {args.requests} requests on {cfg.name}")

    def scfg_for(block_size):
        return ServingConfig(
            num_prefill_instances=2, prefill_dp_per_instance=2,
            num_decode_instances=1, decode_dp_per_instance=2,
            chunk_size=32, t_default=0.05, l_net=0.001,
            max_batch_per_dp=args.max_batch_per_dp, block_size=block_size)

    label = "paged" if args.block_size else "padded"
    ok, peaks = run_sweep(label, cfg, params, scfg_for(args.block_size),
                          fresh, args)
    report = {"block_size": args.block_size,
              "max_batch_per_dp": args.max_batch_per_dp,
              "peak_decode_resident": {label: peaks}}

    if args.compare_padded and args.block_size:
        ok2, padded_peaks = run_sweep("padded", cfg, params, scfg_for(0),
                                      fresh, args)
        ok = ok and ok2
        report["peak_decode_resident"]["padded"] = padded_peaks
        print("\n#### paged vs padded peak concurrent decode requests "
              "(equal KV memory)")
        for sched in peaks:
            p, q = peaks[sched], padded_peaks[sched]
            verdict = "OK" if p > q else "NOT STRICTLY HIGHER"
            print(f"  {sched:>10}: paged={p} padded={q}  {verdict}")
            if p <= q:
                ok = False

    if args.bench_json:
        payload = {}
        if os.path.exists(args.bench_json):
            try:
                with open(args.bench_json) as f:
                    payload = json.load(f)
            except (OSError, ValueError):
                payload = {}        # corrupt/truncated: rebuild our section
        payload["real_plane"] = report
        with open(args.bench_json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"\nupdated {os.path.abspath(args.bench_json)} [real_plane]")

    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
