"""End-to-end driver: serve a small model with batched requests through the
REAL SBS control plane — threaded engines execute true chunked prefill and
decode on jitted JAX forwards; EndForward feedback adapts the interval.

    PYTHONPATH=src python examples/serve_e2e.py [--requests 8] [--arch ID]
"""
import argparse
import random

import jax

from repro.config import ServingConfig, get_arch
from repro.core.types import Request
from repro.models import init_params
from repro.serving.server import RealSBSServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch, reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = random.Random(args.seed)
    reqs = []
    for i in range(args.requests):
        L = rng.randrange(20, 90)
        reqs.append(Request(
            rid=i, arrival_time=i * 0.05, input_len=L,
            output_len=args.max_new,
            tokens=tuple(rng.randrange(cfg.vocab_size) for _ in range(L))))

    scfg = ServingConfig(num_prefill_instances=2, prefill_dp_per_instance=2,
                         chunk_size=32, t_default=0.05, l_net=0.001)
    srv = RealSBSServer(cfg, params, serving_cfg=scfg,
                        max_len=160, max_new=args.max_new)
    print(f"serving {len(reqs)} requests on {cfg.name} "
          f"({scfg.num_prefill_instances} instances × "
          f"{scfg.prefill_dp_per_instance} DPs, chunk={scfg.chunk_size})")
    gens = srv.serve(reqs, timeout=600)
    for g in gens:
        print(f"  rid={g.rid} ttft={g.ttft*1000:7.1f}ms tokens={g.tokens}")
    print(f"done: {len(gens)}/{len(reqs)}; adapted "
          f"I_opt={srv.state.interval.interval*1000:.1f}ms "
          f"T̄_fwd={srv.state.interval.t_fwd*1000:.1f}ms")


if __name__ == "__main__":
    main()
